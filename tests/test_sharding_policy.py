"""Sharding policy rules: every param leaf of every arch gets a wellformed
PartitionSpec under both flavors."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_reduced
from repro.models import model as M
from repro.models.sharding import Policy, make_policy


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("flavor", ["tp", "fsdp_tp"])
def test_param_specs_wellformed(arch, flavor):
    cfg = get_reduced(arch)
    shapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    policy = Policy(mesh=None, flavor=flavor)
    specs = policy.param_specs(shapes)

    def one(path, leaf, spec):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        axes = [a for a in spec if a is not None]
        # no axis used twice
        flat = []
        for a in axes:
            flat.extend(a if isinstance(a, tuple) else (a,))
        assert len(flat) == len(set(flat)), (path, spec)
        for a in flat:
            assert a in ("data", "model", "pod"), (path, spec)

    jax.tree_util.tree_map_with_path(one, shapes, specs)


def test_fsdp_adds_data_axis_to_big_matrices():
    cfg = get_reduced("granite-3-2b")
    shapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    p1 = Policy(mesh=None, flavor="tp").param_specs(shapes)
    p2 = Policy(mesh=None, flavor="fsdp_tp").param_specs(shapes)
    # attention wq is (layers, d, h*dh): tp -> (None, None, model);
    # fsdp_tp -> (None, data, model)
    wq1 = p1["layers"]["attn"]["wq"]["w"]
    wq2 = p2["layers"]["attn"]["wq"]["w"]
    assert wq1 == P(None, None, "model")
    assert wq2 == P(None, "data", "model")


def test_opt_state_always_2d():
    cfg = get_reduced("granite-3-2b")
    shapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    p = Policy(mesh=None, flavor="tp")
    specs = p.param_specs(shapes, for_opt=True)
    assert specs["layers"]["attn"]["wq"]["w"] == P(None, "data", "model")


def test_make_policy_axis_discovery():
    import numpy as np
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pol = make_policy(mesh)
    assert pol.model_axis == "model"
    assert pol.batch_axes == ("data",)
    mesh3 = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    pol3 = make_policy(mesh3)
    assert pol3.batch_axes == ("pod", "data")


def test_scalar_leaves_get_empty_spec():
    p = Policy(mesh=None)
    specs = p.param_specs({"step": jax.ShapeDtypeStruct((), "int32")})
    assert specs["step"] == P()
