"""Join backend conformance suite (hash == sort-merge == numpy oracle).

The two local join backends promise *drop-in identical* output — same
rows, same order (left-row-major; within a left row, matches in the right
table's original row order).  This suite pins that contract over
randomized key distributions x join types x kernel impls, checks the
static-capacity overflow counters trip exactly at capacity, and runs the
distributed join at world sizes 1/2/4 in subprocesses with forced host
devices (the in-process suite keeps the single real CPU device).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import local_ops as L
from repro.core.table import Table

from oracles import np_join

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)

ROWS = 48


def make_sides(dist: str, rng):
    if dist == "unique":
        lk = rng.permutation(np.arange(ROWS, dtype=np.int32))
        rk = rng.permutation(np.arange(ROWS, dtype=np.int32))
    elif dist == "dup10":             # the paper's 10%-key-uniqueness
        nk = max(ROWS // 10, 1)
        lk = rng.integers(0, nk, ROWS).astype(np.int32)
        rk = rng.integers(0, nk, ROWS).astype(np.int32)
    elif dist == "alldup":
        lk = np.full(ROWS, 3, np.int32)
        rk = np.full(ROWS, 3, np.int32)
    elif dist == "empty_left":
        lk = np.zeros(0, np.int32)
        rk = rng.integers(0, 8, ROWS).astype(np.int32)
    elif dist == "empty_right":
        lk = rng.integers(0, 8, ROWS).astype(np.int32)
        rk = np.zeros(0, np.int32)
    else:                             # both sides empty
        lk = rk = np.zeros(0, np.int32)
    left = {"k": lk, "lv": rng.normal(size=len(lk)).astype(np.float32)}
    right = {"k": rk, "rv": rng.normal(size=len(rk)).astype(np.float32)}
    return left, right


def assert_tables_equal(a: dict, b: dict, msg=""):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.nan_to_num(a[k], nan=-1e9),
                                      np.nan_to_num(b[k], nan=-1e9),
                                      err_msg=f"{msg} col={k}")


DISTS = ["unique", "dup10", "alldup", "empty_left", "empty_right",
         "empty_both"]
OUT_CAP = ROWS * ROWS + ROWS          # alldup worst case


@pytest.mark.parametrize("dist", DISTS)
@pytest.mark.parametrize("how", ["inner", "left"])
@pytest.mark.parametrize("kernel_impl", ["ref", "pallas_interpret"])
def test_local_backends_identical(dist, how, kernel_impl, rng):
    left, right = make_sides(dist, rng)
    lt = Table.from_dict(left, capacity=max(len(left["k"]), 1) + 5)
    rt = Table.from_dict(right, capacity=max(len(right["k"]), 1) + 3)
    sm, sm_over = L.join(lt, rt, left_on=["k"], how=how,
                         out_capacity=OUT_CAP, return_overflow=True,
                         impl="sortmerge")
    hj, hj_over = L.join(lt, rt, left_on=["k"], how=how,
                         out_capacity=OUT_CAP, return_overflow=True,
                         impl="hash", num_buckets=8,
                         bucket_capacity=max(ROWS, 8),
                         probe_capacity=max(ROWS, 8),
                         kernel_impl=kernel_impl)
    assert int(sm.nvalid) == int(hj.nvalid)
    assert int(sm_over) == int(hj_over) == 0
    assert_tables_equal(sm.to_numpy(), hj.to_numpy(), f"{dist}/{how}")
    assert_tables_equal(hj.to_numpy(), np_join(left, right, how),
                        f"{dist}/{how} vs oracle")


@pytest.mark.parametrize("how", ["inner", "left"])
def test_multi_key_and_renamed_keys(how, rng):
    left = {"a": rng.integers(0, 4, 30).astype(np.int32),
            "b": rng.integers(0, 3, 30).astype(np.int32),
            "lv": rng.normal(size=30).astype(np.float32)}
    right = {"a": rng.integers(0, 4, 25).astype(np.int32),
             "b": rng.integers(0, 3, 25).astype(np.int32),
             "rv": rng.normal(size=25).astype(np.float32)}
    lt = Table.from_dict(left, capacity=34)
    rt = Table.from_dict(right, capacity=29)
    kw = dict(left_on=["a", "b"], how=how, out_capacity=512,
              return_overflow=True)
    sm, so = L.join(lt, rt, impl="sortmerge", **kw)
    hj, ho = L.join(lt, rt, impl="hash", num_buckets=4,
                    bucket_capacity=32, probe_capacity=32, **kw)
    assert int(so) == int(ho) == 0
    assert_tables_equal(sm.to_numpy(), hj.to_numpy(), f"multikey/{how}")


def test_overflow_counters_trip_at_capacity(rng):
    """alldup keys with slabs below the duplicate count: dropped rows are
    counted, surviving matches are exact."""
    n = 24
    left = {"k": np.full(n, 1, np.int32),
            "lv": np.arange(n, dtype=np.float32)}
    right = {"k": np.full(n, 1, np.int32),
             "rv": np.arange(n, dtype=np.float32)}
    lt = Table.from_dict(left, capacity=n)
    rt = Table.from_dict(right, capacity=n)
    # build-side overflow: chains hold 8 of 24 right rows
    out, over = L.join(lt, rt, left_on=["k"], out_capacity=n * n,
                       return_overflow=True, impl="hash", num_buckets=4,
                       bucket_capacity=8, probe_capacity=n)
    assert int(out.nvalid) == n * 8
    assert int(over) == n - 8
    # probe-side overflow: only 8 of 24 left rows probe
    out, over = L.join(lt, rt, left_on=["k"], out_capacity=n * n,
                       return_overflow=True, impl="hash", num_buckets=4,
                       bucket_capacity=n, probe_capacity=8)
    assert int(out.nvalid) == 8 * n
    assert int(over) == n - 8
    # left join: probe-dropped rows are DROPPED (counted), never emitted
    # as fake unmatched rows with nulled right columns
    out, over = L.join(lt, rt, left_on=["k"], how="left",
                       out_capacity=n * n, return_overflow=True,
                       impl="hash", num_buckets=4, bucket_capacity=n,
                       probe_capacity=8)
    assert int(out.nvalid) == 8 * n
    assert int(over) == n - 8
    assert not np.isnan(out.to_numpy()["rv"]).any()
    # out_capacity overflow: identical truncation to sort-merge
    for impl, kw in (("sortmerge", {}),
                     ("hash", dict(num_buckets=4, bucket_capacity=n,
                                   probe_capacity=n))):
        out, over = L.join(lt, rt, left_on=["k"], out_capacity=100,
                           return_overflow=True, impl=impl, **kw)
        assert int(out.nvalid) == 100, impl
        assert int(over) == n * n - 100, impl


def test_env_default_backend(monkeypatch, rng):
    # "unique" keys: within the auto-sizing heuristic's contract (heavy
    # duplication needs explicit bucket sizing, see default_hash_join_sizes)
    left, right = make_sides("unique", rng)
    lt = Table.from_dict(left, capacity=ROWS)
    rt = Table.from_dict(right, capacity=ROWS)
    monkeypatch.setenv("REPRO_JOIN_IMPL", "hash")
    hj = L.join(lt, rt, left_on=["k"], out_capacity=OUT_CAP)
    monkeypatch.setenv("REPRO_JOIN_IMPL", "sortmerge")
    sm = L.join(lt, rt, left_on=["k"], out_capacity=OUT_CAP)
    assert_tables_equal(sm.to_numpy(), hj.to_numpy(), "env dispatch")
    with pytest.raises(ValueError):
        L.join(lt, rt, left_on=["k"], impl="nope")


@pytest.mark.parametrize("world", [1, 2, 4])
def test_dist_join_conformance(world):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={world}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable,
         os.path.join(HERE, "dist", "join_conformance.py"), str(world)],
        env=env, capture_output=True, text=True, timeout=1800)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, f"join conformance failed (world={world})"
    assert "JOIN CONFORMANCE PASSED" in proc.stdout


def test_fused_join_plan_three_scatters():
    """The fused bucketing path issues ONE stacked scatter per slab
    family: build slabs, probe slabs, and the packed match-counts/probed
    result — exactly three ``scatter`` eqns in the join plan's jaxpr,
    regardless of key-column count."""
    import jax.numpy as jnp
    from repro.kernels.hash_join import hash_join_plan
    from test_groupby_backends import _count_scatter_eqns
    n = 64
    bits = (jnp.arange(n, dtype=jnp.int32),
            jnp.arange(n, dtype=jnp.int32) % 7)
    valid = jnp.ones((n,), bool)
    cnt = _count_scatter_eqns(
        lambda b, v: hash_join_plan(b, v, b, v, num_buckets=8,
                                    bucket_capacity=16, probe_capacity=16,
                                    impl="ref"), bits, valid)
    assert cnt == 3, cnt
