"""Driver for zero-row / empty-shard coverage of the distributed
operators (dist_join, dist_groupby, dist_sort, dist_isin) at world
sizes 1/2/4 — subprocess workers with forced host devices."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)


@pytest.mark.parametrize("world", [1, 2, 4])
def test_empty_table_conformance(world):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={world}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable,
         os.path.join(HERE, "dist", "empty_conformance.py"), str(world)],
        env=env, capture_output=True, text=True, timeout=1800)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, f"empty conformance failed (world={world})"
    assert "EMPTY CONFORMANCE PASSED" in proc.stdout
