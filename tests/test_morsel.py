"""Out-of-core morsel execution tests (core/morsel.py).

Host-side unit tests for the chunking source and the k-way run merge,
input-validation contracts, the distribute_table satellite fixes
(capacity validation, int32-range key refusal), and the world 1/2/4
subprocess conformance runs pinning chunked == monolithic.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core import dist_ops as D
from repro.core import morsel as M
from repro.core.context import make_context

from oracles import np_sort_values

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)


@pytest.fixture(scope="module")
def ctx1():
    return make_context(jax.make_mesh((1,), ("rows",)))


# --------------------------------------------------------------------------
# ChunkedTable source
# --------------------------------------------------------------------------


def test_chunked_table_chunking():
    t = M.ChunkedTable({"a": np.arange(10)}, chunk_rows=4)
    assert t.num_chunks == 3
    assert [len(c["a"]) for c in t.chunks()] == [4, 4, 2]
    np.testing.assert_array_equal(
        np.concatenate([c["a"] for c in t.chunks()]), np.arange(10))


def test_chunked_table_empty_yields_one_terminal_morsel():
    t = M.ChunkedTable({"a": np.zeros(0, np.int32)}, chunk_rows=4)
    assert t.num_chunks == 1
    assert [len(c["a"]) for c in t.chunks()] == [0]


def test_chunked_table_fixed_capacity_per_shard():
    t = M.ChunkedTable({"a": np.arange(10)}, chunk_rows=4)
    assert t.capacity_per_shard(4) == 1
    assert t.capacity_per_shard(1) == 4


def test_chunked_table_validation():
    with pytest.raises(ValueError, match="chunk_rows"):
        M.ChunkedTable({"a": np.arange(3)}, chunk_rows=0)
    with pytest.raises(ValueError, match="equal length"):
        M.ChunkedTable({"a": np.arange(3), "b": np.arange(4)}, 2)
    with pytest.raises(ValueError, match="at least one column"):
        M.ChunkedTable({}, 2)


def test_chunked_table_distribute_constant_capacity(ctx1):
    t = M.ChunkedTable({"a": np.arange(10, dtype=np.int32)}, chunk_rows=4)
    caps = [g.capacity for g in t.distribute(ctx1)]
    assert caps == [4, 4, 4]          # last (smaller) chunk reuses the cap


# --------------------------------------------------------------------------
# k-way run merge (host side)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("ascending", [True, False])
def test_merge_sorted_runs_matches_stable_sort(ascending, rng):
    data = {"k": rng.integers(0, 9, 200).astype(np.int32),
            "v": np.arange(200, dtype=np.int32)}
    want = np_sort_values(data, ["k"], ascending=ascending)
    runs = []
    for lo in range(0, 200, 48):      # consecutive chunks, chunk-local sort
        chunk = {c: v[lo:lo + 48] for c, v in data.items()}
        runs.append(np_sort_values(chunk, ["k"], ascending=ascending))
    got = M.merge_sorted_runs(runs, ["k"], ascending=ascending)
    for c in want:                    # ties resolve to original row order
        np.testing.assert_array_equal(got[c], want[c], err_msg=c)


def test_merge_sorted_runs_descending_floats_and_multikey(rng):
    data = {"k": rng.integers(0, 5, 120).astype(np.float32),
            "s": rng.integers(0, 3, 120).astype(np.int32),
            "v": np.arange(120, dtype=np.int32)}
    want = np_sort_values(data, ["k", "s"], ascending=False)
    runs = [np_sort_values({c: v[lo:lo + 40] for c, v in data.items()},
                           ["k", "s"], ascending=False)
            for lo in range(0, 120, 40)]
    got = M.merge_sorted_runs(runs, ["k", "s"], ascending=False)
    for c in want:
        np.testing.assert_array_equal(got[c], want[c], err_msg=c)


def test_merge_sorted_runs_degenerate():
    assert M.merge_sorted_runs([], ["k"]) == {}
    one = {"k": np.arange(4, dtype=np.int32)}
    np.testing.assert_array_equal(
        M.merge_sorted_runs([one], ["k"])["k"], one["k"])
    empty = {"k": np.zeros(0, np.int32)}
    out = M.merge_sorted_runs([empty, one, empty], ["k"])
    np.testing.assert_array_equal(out["k"], one["k"])


# --------------------------------------------------------------------------
# operator argument validation
# --------------------------------------------------------------------------


def test_restream_left_join_rejected(ctx1):
    d = {"k": np.arange(4, dtype=np.int32)}
    with pytest.raises(ValueError, match="restream"):
        M.chunked_dist_join(ctx1, d, d, left_on=["k"], how="left",
                            build="restream")
    with pytest.raises(ValueError, match="how"):
        M.chunked_dist_join(ctx1, d, d, left_on=["k"], how="outer")
    with pytest.raises(ValueError, match="build"):
        M.chunked_dist_join(ctx1, d, d, left_on=["k"], build="nope")


# --------------------------------------------------------------------------
# distribute_table satellite fixes (capacity validation, dtype contract)
# --------------------------------------------------------------------------


def test_distribute_table_rejects_nonpositive_capacity(ctx1):
    data = {"k": np.arange(4, dtype=np.int32)}
    for bad in (0, -3):
        with pytest.raises(ValueError, match="must be positive"):
            D.distribute_table(ctx1, data, capacity_per_shard=bad)
    # None still means rows-per-shard (never coerced through `or`)
    g = D.distribute_table(ctx1, data, capacity_per_shard=None)
    assert g.capacity == 4


def test_distribute_table_rejects_out_of_int32_keys(ctx1):
    bad = {"k": np.array([1, 1 + 2 ** 32], dtype=np.int64)}
    with pytest.raises(ValueError, match="int32 range"):
        D.distribute_table(ctx1, bad)
    ok = D.distribute_table(
        ctx1, {"k": np.array([1, 2 ** 31 - 1], dtype=np.int64)})
    np.testing.assert_array_equal(
        np.asarray(ok.columns["k"]), [1, 2 ** 31 - 1])


def test_join_keys_around_2_31_no_false_matches(ctx1):
    """Regression: int64 keys 2^32 apart used to silently truncate to the
    same int32 bits and join as a false match; now ingestion raises."""
    left = {"k": np.array([1], dtype=np.int64),
            "lv": np.array([10.0], np.float32)}
    right = {"k": np.array([1 + 2 ** 32], dtype=np.int64),
             "rv": np.array([20.0], np.float32)}
    with pytest.raises(ValueError, match="false join matches"):
        M.chunked_dist_join(ctx1, left, right, left_on=["k"])
    # in-range int64 keys join exactly (no truncation of 2^31 - 1)
    right_ok = {"k": np.array([2 ** 31 - 1, 1], dtype=np.int64),
                "rv": np.array([20.0, 30.0], np.float32)}
    out, dropped = M.chunked_dist_join(ctx1, left, right_ok,
                                       left_on=["k"])
    assert dropped == 0
    np.testing.assert_array_equal(out["k"], [1])
    np.testing.assert_array_equal(out["rv"], [30.0])


# --------------------------------------------------------------------------
# world 1/2/4 conformance (subprocess, forced host devices)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("world", [1, 2, 4])
def test_morsel_conformance(world):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={world}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable,
         os.path.join(HERE, "dist", "morsel_conformance.py"), str(world)],
        env=env, capture_output=True, text=True, timeout=1800)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, f"morsel conformance failed (world={world})"
    assert "MORSEL CONFORMANCE PASSED" in proc.stdout
