"""MoE routing/dispatch tests (single-device paths; the shard_map EP path
is exercised on 8 devices in tests/dist/dist_checks.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import moe as Moe


def _cfg():
    return get_reduced("qwen3-moe-235b-a22b")


def test_router_weights_normalized():
    cfg = _cfg()
    router = jax.random.normal(jax.random.PRNGKey(0),
                               (cfg.d_model, cfg.n_experts), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model))
    w, ids, aux = Moe._route(router, x, cfg.top_k)
    assert w.shape == (16, cfg.top_k)
    assert ids.shape == (16, cfg.top_k)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, axis=-1)), 1.0,
                               rtol=1e-5)
    assert ((np.asarray(ids) >= 0)
            & (np.asarray(ids) < cfg.n_experts)).all()
    # balanced-ish random routing -> aux near 1 (perfectly balanced == 1)
    assert 0.5 < float(aux) < 4.0


def test_moe_dense_shapes_finite():
    cfg = _cfg()
    p = Moe.moe_init(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model),
                          jnp.float32)
    y, aux = Moe.moe_dense(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert np.isfinite(float(aux))


def test_moe_apply_without_mesh_falls_back_to_dense():
    cfg = _cfg()
    p = Moe.moe_init(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 8, cfg.d_model))
    y1, a1 = Moe.moe_apply(p, cfg, x, policy=None)
    y2, a2 = Moe.moe_dense(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), rtol=1e-5)


def test_expert_padding():
    """granite has 40 experts -> padded to 48; pads take no tokens."""
    cfg = get_reduced("granite-moe-3b-a800m")
    from repro.configs import get_config
    full = get_config("granite-moe-3b-a800m")
    assert full.n_experts == 40
    assert Moe.n_experts_padded(full) == 48
    p = Moe.moe_init(jax.random.PRNGKey(6), cfg)
    # router only has n_experts outputs -> ids < n_experts always
    x = jax.random.normal(jax.random.PRNGKey(7), (64, cfg.d_model))
    _, ids, _ = Moe._route(p["router"], x, cfg.top_k)
    assert (np.asarray(ids) < cfg.n_experts).all()


def test_moe_grad_flows_to_router():
    cfg = _cfg()
    p = Moe.moe_init(jax.random.PRNGKey(8), cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 8, cfg.d_model))

    def loss(p):
        y, aux = Moe.moe_dense(p, cfg, x)
        return jnp.sum(y.astype(jnp.float32) ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
