"""Hypothesis property-based tests on the table engine's invariants.

Strategy: small random tables (int key column + float value column, random
capacity padding).  Each property is an algebraic law of the relational
operators — the kind of invariant the HPTMT composition model relies on.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import local_ops as L
from repro.core.partition import hash_columns, partition_ids
from repro.core.table import Table

from conftest import as_sets

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@st.composite
def tables(draw, max_rows=24, key_range=8):
    n = draw(st.integers(0, max_rows))
    pad = draw(st.integers(0, 8))
    keys = draw(st.lists(st.integers(0, key_range - 1),
                         min_size=n, max_size=n))
    vals = draw(st.lists(
        st.floats(-100, 100, allow_nan=False, width=32),
        min_size=n, max_size=n))
    return Table.from_dict(
        {"k": np.asarray(keys, np.int32),
         "v": np.asarray(vals, np.float32)},
        capacity=max(n + pad, 1))


@given(tables())
def test_nvalid_never_exceeds_capacity(t):
    assert int(t.nvalid) <= t.capacity


@given(tables())
def test_sort_is_permutation_and_ordered(t):
    out = L.sort_values(t, ["k"])
    assert int(out.nvalid) == int(t.nvalid)
    got = out.to_numpy()
    want = t.to_numpy()
    np.testing.assert_array_equal(np.sort(got["k"]), np.sort(want["k"]))
    assert (np.diff(got["k"]) >= 0).all()
    # row payloads stay attached to their keys (multiset of pairs equal)
    assert as_sets(got) == as_sets(want)


@given(tables())
def test_dedup_subset_of_input_and_unique(t):
    out = L.drop_duplicates(t, ["k"]).to_numpy()
    keys = t.to_numpy()["k"]
    assert set(out["k"]) == set(keys)
    assert len(out["k"]) == len(np.unique(keys))


@given(tables(), st.integers(0, 7))
def test_select_conjunction_composes(t, cut):
    m1 = t["k"] >= cut
    m2 = t["k"] % 2 == 0
    seq = L.select(L.select(t, m1), L.select(t, m1)["k"] % 2 == 0)
    joint = L.select(t, m1 & m2)
    assert as_sets(seq.to_numpy()) == as_sets(joint.to_numpy())


@given(tables())
def test_groupby_sum_preserves_total(t):
    out = L.groupby_aggregate(t, ["k"], {"v": "sum"})
    total_groups = float(L.aggregate(out, "v_sum", "sum"))
    total_rows = float(L.aggregate(t, "v", "sum"))
    np.testing.assert_allclose(total_groups, total_rows, rtol=1e-4,
                               atol=1e-4)


@st.composite
def mixed_key_tables(draw, max_rows=20):
    """Tables with a mixed-dtype (int32, float32) key pair and an
    integer-valued float value column (exact sums in any addition order,
    so the groupby backends must agree bit-for-bit)."""
    n = draw(st.integers(0, max_rows))
    pad = draw(st.integers(0, 6))
    ik = draw(st.lists(st.integers(0, 5), min_size=n, max_size=n))
    # float keys off a small exact grid; no -0.0, no NaN (out of contract)
    fk = draw(st.lists(st.sampled_from([x * 0.5 for x in range(-4, 5)]),
                       min_size=n, max_size=n))
    iv = draw(st.lists(st.integers(-50, 50), min_size=n, max_size=n))
    return Table.from_dict(
        {"ik": np.asarray(ik, np.int32),
         "fk": np.asarray(fk, np.float32),
         "v": np.asarray(iv, np.float32)},
        capacity=max(n + pad, 1))


@given(mixed_key_tables(),
       st.lists(st.booleans(), min_size=1, max_size=3))
def test_sort_backends_bit_identical_and_match_oracle(t, asc):
    """OrderBy invariants over mixed-dtype multi-key tables with per-key
    ascending flags: the radix and xla backends are bit-identical (full
    columns — padding rows stay last in the same order), and both match
    the pandas-semantics oracle including stability of ties (stable
    semantics pin tie order to original row order on every side)."""
    from oracles import np_sort_values

    by = ["ik", "fk", "v"][: len(asc)]
    x = L.sort_values(t, by, asc, impl="xla")
    r = L.sort_values(t, by, asc, impl="radix")
    assert int(x.nvalid) == int(r.nvalid) == int(t.nvalid)
    for c in t.names:
        np.testing.assert_array_equal(np.asarray(x.columns[c]),
                                      np.asarray(r.columns[c]),
                                      err_msg=c)
    data = t.to_numpy()
    want = np_sort_values(data, by, asc)
    got = r.to_numpy()
    for c in want:
        np.testing.assert_array_equal(got[c], want[c].astype(got[c].dtype),
                                      err_msg=f"oracle {c}")


@given(tables(), st.integers(0, 7))
def test_compact_is_stable_boolean_argsort(t, cut):
    """The 1-bit radix fast path behind compact/select: bit-identical to
    the stable argsort compaction, padding rows preserved in order."""
    keep = (t["k"] >= cut) & t.valid_mask
    got = L.compact(t, keep)
    perm = jnp.argsort(jnp.logical_not(keep), stable=True)
    want = t.gather_rows(perm, jnp.sum(keep, dtype=jnp.int32))
    assert int(got.nvalid) == int(want.nvalid)
    for c in t.names:
        np.testing.assert_array_equal(np.asarray(got.columns[c]),
                                      np.asarray(want.columns[c]),
                                      err_msg=c)


@given(mixed_key_tables())
def test_groupby_backends_bit_identical(t):
    aggs = {"v": ["sum", "count", "mean", "min", "max"]}
    s = L.groupby_aggregate(t, ["ik", "fk"], aggs, impl="sort")
    h, over = L.groupby_aggregate(t, ["ik", "fk"], aggs, impl="hash",
                                  return_overflow=True)
    assert int(over) == 0
    assert int(s.nvalid) == int(h.nvalid)
    sn, hn = s.to_numpy(), h.to_numpy()
    assert set(sn) == set(hn)
    for c in sn:
        assert sn[c].dtype == hn[c].dtype, c
        np.testing.assert_array_equal(sn[c], hn[c], err_msg=c)
    assert hn["v_count"].dtype == np.int32


@given(mixed_key_tables())
def test_dedup_backends_bit_identical(t):
    s = L.drop_duplicates(t, ["ik", "fk"], impl="sort")
    h, over = L.drop_duplicates(t, ["ik", "fk"], impl="hash",
                                return_overflow=True)
    assert int(over) == 0
    sn, hn = s.to_numpy(), h.to_numpy()
    for c in sn:
        np.testing.assert_array_equal(sn[c], hn[c], err_msg=c)


@given(tables(), tables())
def test_join_row_count_is_sum_of_key_products(a, b):
    na = a.to_numpy()["k"]
    nb = b.to_numpy()["k"]
    want = sum(int((na == k).sum()) * int((nb == k).sum())
               for k in np.unique(na))
    out, overflow = L.join(a, b, left_on=["k"], out_capacity=1024,
                           return_overflow=True)
    assert int(out.nvalid) == want
    assert int(overflow) == 0


@given(tables(), tables())
def test_intersect_difference_partition_left(a, b):
    """difference(a,b) ∪ semijoin(a,b) == a (as key sets)."""
    inter = set(L.intersect(a, b, ["k"]).to_numpy()["k"])
    diff = set(L.difference(a, b, ["k"]).to_numpy()["k"])
    keys = set(a.to_numpy()["k"])
    assert inter | diff == keys
    assert inter & diff == set()


@given(tables())
def test_union_with_self_is_dedup(t):
    u = L.union(t, t).to_numpy()
    d = L.drop_duplicates(t).to_numpy()
    assert as_sets(u) == as_sets(d)


@given(mixed_key_tables(), mixed_key_tables())
def test_semi_backends_bit_identical_mixed_keys(a, b):
    """The semi-join backends on mixed-dtype (int32, float32) multi-key
    tables: the sortmerge and hash membership masks are bit-identical
    over the FULL capacity (padding rows are never members), and
    intersect/difference/union outputs match bit-for-bit."""
    on = ["ik", "fk"]
    ms = L.semi_mask(a, b, on, impl="sortmerge")
    mh, over = L.semi_mask(a, b, on, impl="hash", return_overflow=True)
    assert int(over) == 0
    np.testing.assert_array_equal(np.asarray(ms), np.asarray(mh))
    assert not np.asarray(ms)[int(a.nvalid):].any()
    for op in ("intersect", "difference"):
        s = getattr(L, op)(a, b, on=on, impl="sortmerge")
        h = getattr(L, op)(a, b, on=on, impl="hash")
        assert int(s.nvalid) == int(h.nvalid), op
        sn, hn = s.to_numpy(), h.to_numpy()
        for c in sn:
            assert sn[c].dtype == hn[c].dtype, (op, c)
            np.testing.assert_array_equal(sn[c], hn[c],
                                          err_msg=f"{op} {c}")
    us = L.union(a, b, on=on, impl="sort").to_numpy()
    uh = L.union(a, b, on=on, impl="hash").to_numpy()
    for c in us:
        np.testing.assert_array_equal(us[c], uh[c],
                                      err_msg=f"union {c}")


@given(mixed_key_tables(), mixed_key_tables())
def test_intersect_difference_partition_mixed_keys(a, b):
    """difference(a,b) ⊎ semijoin(a,b) == a (as row multisets) on
    mixed-dtype multi-key tables — for BOTH semi backends."""
    an = a.to_numpy()
    rows = as_sets(an)
    for impl in ("sortmerge", "hash"):
        mask = np.asarray(L.semi_mask(a, b, ["ik", "fk"],
                                      impl=impl))[:int(a.nvalid)]
        inside = as_sets({c: v[mask] for c, v in an.items()})
        d = L.difference(a, b, on=["ik", "fk"], impl=impl).to_numpy()
        assert sorted(inside + as_sets(d)) == rows, impl


@given(mixed_key_tables(), mixed_key_tables())
def test_union_matches_dedup_oracle_mixed_keys(a, b):
    """union(a, b, on) == drop_duplicates(concat(a, b), on): keep-first
    canonical output, a's rows winning key ties."""
    from oracles import np_drop_duplicates

    an, bn = a.to_numpy(), b.to_numpy()
    cat = {c: np.concatenate([an[c], bn[c]]) for c in an}
    want = np_drop_duplicates(cat, ["ik", "fk"])
    got = L.union(a, b, on=["ik", "fk"]).to_numpy()
    for c in want:
        np.testing.assert_array_equal(got[c],
                                      want[c].astype(got[c].dtype),
                                      err_msg=c)


@given(tables())
def test_concat_counts_add(t):
    out = L.concat(t, t)
    assert int(out.nvalid) == 2 * int(t.nvalid)


@given(tables(), st.integers(1, 8))
def test_partition_ids_in_range_and_hash_deterministic(t, parts):
    pid = np.asarray(partition_ids(t, ["k"], parts))
    assert ((pid >= 0) & (pid < parts)).all()
    h1 = np.asarray(hash_columns([t["k"]]))
    h2 = np.asarray(hash_columns([t["k"]]))
    np.testing.assert_array_equal(h1, h2)
    # equal keys hash equal -> equal partition (valid rows only; padding
    # rows are masked to pid 0 by design)
    n = int(t.nvalid)
    keys = np.asarray(t["k"])[:n]
    for u in np.unique(keys):
        assert len(np.unique(pid[:n][keys == u])) == 1


@given(st.lists(st.floats(-1e5, 1e5, allow_nan=False, width=32),
                min_size=1, max_size=32))
def test_float_hash_normalizes_negative_zero(vals):
    col = jnp.asarray(np.asarray(vals, np.float32))
    h_pos = np.asarray(hash_columns([jnp.abs(col) * 0.0]))
    h_neg = np.asarray(hash_columns([-(jnp.abs(col) * 0.0)]))
    np.testing.assert_array_equal(h_pos, h_neg)
