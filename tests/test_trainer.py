"""Fault-tolerant runtime: restart-equivalence, straggler monitor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import lm_batch_at
from repro.optim import adamw
from repro.runtime.trainer import (FailureInjector, StepTimeMonitor,
                                   Trainer, run_with_restarts)

VOCAB, BATCH, SEQ = 64, 4, 16


def _make_step():
    cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=0, min_lr_ratio=1.0)

    def loss_fn(params, batch):
        x = jax.nn.one_hot(batch["tokens"], VOCAB) @ params["w"]
        logits = x @ params["w"].T
        lab = jax.nn.one_hot(batch["labels"], VOCAB)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * lab, -1))

    @jax.jit
    def step(state, batch):
        params, opt = state
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        params, opt, m = adamw.update(params, g, opt, cfg)
        return (params, opt), dict(m, loss=loss)

    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (VOCAB, 32))
              * 0.1}
    opt = adamw.init(params, cfg)
    return step, (params, opt)


def _batches(start):
    def gen():
        s = start
        while True:
            b = lm_batch_at(s, vocab=VOCAB, batch=BATCH, seq=SEQ)
            yield {k: jnp.asarray(v) for k, v in b.items()}
            s += 1
    return gen()


def test_uninterrupted_run(tmp_path):
    step, state = _make_step()
    tr = Trainer(step_fn=step, ckpt_dir=str(tmp_path), ckpt_every=5)

    def const_batches():
        b = lm_batch_at(0, vocab=VOCAB, batch=BATCH, seq=SEQ)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        while True:
            yield b

    final, hist = tr.run(state, const_batches(), n_steps=20, log_every=0)
    assert len(hist) == 20
    assert hist[-1]["loss"] < hist[0]["loss"]   # overfits a fixed batch


def test_restart_after_failure_is_bit_identical(tmp_path):
    """Checkpoint/restart end state must equal the uninterrupted run."""
    step, state0 = _make_step()

    # uninterrupted reference
    ref_dir = str(tmp_path / "ref")
    tr_ref = Trainer(step_fn=step, ckpt_dir=ref_dir, ckpt_every=5)
    ref_state, _ = tr_ref.run(state0, _batches(0), n_steps=12, log_every=0)

    # failure at step 7 -> restore from ckpt step 5 -> resume
    fail_dir = str(tmp_path / "fail")
    tr = Trainer(step_fn=step, ckpt_dir=fail_dir, ckpt_every=5,
                 failure=FailureInjector(fail_at=7))
    final_state, hist = run_with_restarts(
        _batches, tr, state0, n_steps=12, log_fn=lambda *_: None)

    for a, b in zip(jax.tree_util.tree_leaves(ref_state),
                    jax.tree_util.tree_leaves(final_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_with_restarts_gives_up(tmp_path):
    step, state = _make_step()

    class AlwaysFail(FailureInjector):
        def check(self, s):
            raise RuntimeError("boom")

    tr = Trainer(step_fn=step, ckpt_dir=str(tmp_path), ckpt_every=5,
                 failure=AlwaysFail())
    with pytest.raises(RuntimeError):
        run_with_restarts(_batches, tr, state, n_steps=5,
                          max_restarts=2, log_fn=lambda *_: None)


def test_straggler_monitor():
    mon = StepTimeMonitor(alpha=0.5, threshold=2.0)
    assert mon.record(0, 1.0) is False       # first sample seeds the mean
    assert mon.record(1, 1.1) is False
    assert mon.record(2, 10.0) is True       # 10x the mean -> flagged
    assert mon.stragglers[0][0] == 2
    # mean keeps tracking; a normal step afterwards is not flagged
    assert mon.record(3, 1.0) is False


def test_restore_or_init_prefers_checkpoint(tmp_path):
    step, state = _make_step()
    tr = Trainer(step_fn=step, ckpt_dir=str(tmp_path), ckpt_every=2)
    s, hist = tr.run(state, _batches(0), n_steps=4, log_every=0)
    start, restored = tr.restore_or_init(state)
    assert start == 4
    for a, b in zip(jax.tree_util.tree_leaves(s),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
