"""Roofline machinery: HLO collective parser + three-term analysis."""
import numpy as np
import pytest

from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline,
                                     model_flops_for)
from repro.roofline.hlo import CollectiveStats, parse_collectives


def test_parse_allreduce_iota_groups():
    hlo = ('%ar = f32[1024,256]{1,0} all-reduce(%x), '
           'replica_groups=[16,32]<=[512], to_apply=%add')
    s = parse_collectives(hlo)
    assert s.counts["all-reduce"] == 1
    want = 1024 * 256 * 4
    assert s.result_bytes["all-reduce"] == want
    # ring factor 2(g-1)/g with g=32
    np.testing.assert_allclose(s.link_bytes["all-reduce"],
                               want * 2 * 31 / 32)


def test_parse_allgather_explicit_groups():
    hlo = ('%ag = bf16[64,128]{1,0} all-gather(%x), dimensions={0}, '
           'replica_groups={{0,1,2,3},{4,5,6,7}}')
    s = parse_collectives(hlo)
    want = 64 * 128 * 2
    assert s.result_bytes["all-gather"] == want
    np.testing.assert_allclose(s.link_bytes["all-gather"], want * 3 / 4)


def test_parse_reduce_scatter():
    hlo = ('%rs = f32[32,64]{1,0} reduce-scatter(%x), dimensions={0}, '
           'replica_groups=[8,64]<=[512], to_apply=%add')
    s = parse_collectives(hlo)
    want = 32 * 64 * 4
    # reduce-scatter result is the shard; link bytes ~ (g-1)*result
    np.testing.assert_allclose(s.link_bytes["reduce-scatter"], want * 63)


def test_parse_all_to_all_and_permute():
    hlo = """
%a2a = s32[16,16]{1,0} all-to-all(%x), replica_groups=[32,16]<=[512]
%cp = f32[8,8]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
"""
    s = parse_collectives(hlo)
    assert s.counts["all-to-all"] == 1
    assert s.counts["collective-permute"] == 1
    np.testing.assert_allclose(s.link_bytes["all-to-all"],
                               16 * 16 * 4 * 15 / 16)
    np.testing.assert_allclose(s.link_bytes["collective-permute"],
                               8 * 8 * 4)


def test_parse_async_start_done_counted_once():
    hlo = """
%s = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-gather-start(%x), replica_groups=[2,4]<=[8]
%d = f32[4,4]{1,0} all-gather-done(%s)
"""
    s = parse_collectives(hlo)
    assert s.counts.get("all-gather", 0) == 1


def test_parse_tuple_result():
    hlo = ('%ar = (f32[8]{0}, bf16[16]{0}) all-reduce(%a, %b), '
           'replica_groups=[4,8]<=[32], to_apply=%add')
    s = parse_collectives(hlo)
    assert s.result_bytes["all-reduce"] == 8 * 4 + 16 * 2


def test_parse_ignores_non_collectives():
    hlo = "%m = f32[128,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}"
    s = parse_collectives(hlo)
    assert s.total_link_bytes == 0
    assert s.counts == {}


def test_roofline_terms_and_bound():
    stats = CollectiveStats(counts={"all-reduce": 1},
                            result_bytes={"all-reduce": 1e9},
                            link_bytes={"all-reduce": 2e9})
    r = Roofline(arch="x", cell="train_4k", mesh="16x16",
                 flops_per_dev=1e12, bytes_per_dev=1e11,
                 collective=stats, model_flops=6e15, n_chips=256)
    np.testing.assert_allclose(r.compute_s, 1e12 / PEAK_FLOPS)
    np.testing.assert_allclose(r.memory_s, 1e11 / HBM_BW)
    np.testing.assert_allclose(r.collective_s, 2e9 / ICI_BW)
    assert r.bound == "memory"
    assert r.step_s == max(r.compute_s, r.memory_s, r.collective_s)
    assert 0 < r.mfu < 1.0
    d = r.to_dict()
    assert d["bound"] == "memory"


def test_model_flops_train_vs_decode():
    from repro.configs import get_config
    cfg = get_config("granite-3-2b")
    n = cfg.active_param_count()
    train = model_flops_for(cfg, "train_4k")
    np.testing.assert_allclose(train, 6.0 * n * 256 * 4096)
    dec = model_flops_for(cfg, "decode_32k")
    np.testing.assert_allclose(dec, 2.0 * n * 128)
    pre = model_flops_for(cfg, "prefill_32k")
    np.testing.assert_allclose(pre, 2.0 * n * 32 * 32768)
