"""Regression: failure BEFORE the first periodic checkpoint must restart
from a step-0 snapshot, not the (donated) init_state."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw
from repro.runtime.trainer import (FailureInjector, Trainer,
                                   run_with_restarts)


def test_restart_before_first_checkpoint_with_donation(tmp_path):
    cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=0, min_lr_ratio=1.0)

    def loss_fn(params, batch):
        return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)

    @jax.jit
    def _step(params, opt, batch):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        params, opt, m = adamw.update(params, g, opt, cfg)
        return params, opt, dict(m, loss=loss)

    donating = jax.jit(
        lambda p, o, b: _step(p, o, b), donate_argnums=(0, 1))

    def step_fn(state, batch):
        p, o = state
        p, o, m = donating(p, o, batch)
        return (p, o), m

    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))

    def batches(start):
        while True:
            yield {"x": X, "y": y}

    params = {"w": jnp.zeros((4,), jnp.float32)}
    state0 = (params, adamw.init(params, cfg))
    # fail at step 3, ckpt_every 100 -> no periodic ckpt exists yet; the
    # donated state0 buffers are dead -> must restore the step-0 snapshot
    tr = Trainer(step_fn=step_fn, ckpt_dir=str(tmp_path), ckpt_every=100,
                 failure=FailureInjector(fail_at=3))
    state, hist = run_with_restarts(batches, tr, state0, n_steps=6,
                                    log_fn=lambda *_: None)
    assert len(hist) == 6
    assert np.isfinite(hist[-1]["loss"])
