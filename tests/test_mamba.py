"""Mamba block tests: chunked scan vs ref, decode-step consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.kernels.mamba_scan.ref import selective_scan_ref
from repro.models import mamba as Mb


def _cfg():
    cfg = get_reduced("falcon-mamba-7b")
    return cfg


def test_chunked_xla_scan_matches_ref():
    B, S, E, N = 2, 64, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (B, S, E), jnp.float32)
    delta = jax.nn.softplus(jax.random.normal(ks[1], (B, S, E)))
    A = -jnp.exp(jax.random.normal(ks[2], (E, N)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    D = jax.random.normal(ks[5], (E,))
    h0 = jnp.zeros((B, E, N), jnp.float32)
    y_ref, h_ref = selective_scan_ref(x, delta, A, Bm, Cm, D)
    for chunk in (16, 32, 64):
        y, hT = Mb._scan_chunked_xla(x, delta, A, Bm, Cm, D, h0, chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref),
                                   rtol=1e-5, atol=1e-5)


def test_mamba_apply_shapes_and_finite():
    cfg = _cfg()
    p = Mb.mamba_init(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model),
                          jnp.float32)
    y, state = Mb.mamba_apply(p, cfg, x, return_state=True)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert state["conv"].shape == (2, cfg.ssm_conv - 1, cfg.d_inner)
    assert state["ssm"].shape == (2, cfg.d_inner, cfg.ssm_state)


def test_mamba_full_vs_stepwise_decode():
    """Running the scan token-by-token with mamba_step must reproduce the
    full-sequence forward — the KV-cache-equivalence test for SSMs."""
    cfg = _cfg()
    p = Mb.mamba_init(jax.random.PRNGKey(3), cfg)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, cfg.d_model),
                          jnp.float32) * 0.5
    y_full, _ = Mb.mamba_apply(p, cfg, x, scan_chunk=S)

    state = {"conv": jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner),
                               jnp.float32),
             "ssm": jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32)}
    ys = []
    for t in range(S):
        y_t, state = Mb.mamba_step(p, cfg, x[:, t:t + 1], state)
        ys.append(y_t)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_steps, np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_mamba_grad_finite():
    cfg = _cfg()
    p = Mb.mamba_init(jax.random.PRNGKey(5), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 32, cfg.d_model))

    def loss(p):
        y, _ = Mb.mamba_apply(p, cfg, x, scan_chunk=8)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    g = jax.grad(loss)(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_mamba_conv_state_matches_tail():
    cfg = _cfg()
    p = Mb.mamba_init(jax.random.PRNGKey(7), cfg)
    B, S = 1, 8
    x = jax.random.normal(jax.random.PRNGKey(8), (B, S, cfg.d_model))
    _, state = Mb.mamba_apply(p, cfg, x, return_state=True)
    # conv state is the last K-1 in_proj activations
    xz = x.astype(jnp.bfloat16) @ p["in_proj"]["w"].astype(jnp.bfloat16)
    x_in = xz[..., :cfg.d_inner].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(state["conv"]),
                               np.asarray(x_in[:, S - (cfg.ssm_conv - 1):]),
                               rtol=1e-5, atol=1e-5)
