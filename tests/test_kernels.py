"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle.

Per the assignment: every Pallas kernel is validated on CPU in interpret
mode against its pure-jnp reference across a sweep of shapes and dtypes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hash_join.kernel import bucket_probe_buckets
from repro.kernels.hash_join.ref import bucket_probe_ref
from repro.kernels.hash_partition import (partition_plan,
                                          radix_histogram_ranks)
from repro.kernels.hash_partition.ref import radix_histogram_ranks_ref
from repro.kernels.mamba_scan import selective_scan
from repro.kernels.mamba_scan.ref import selective_scan_ref
from repro.kernels import bucketing
from repro.kernels.radix_sort import (grouped_ranks, radix_permutation,
                                      stable_partition_perm)
from repro.kernels.radix_sort.kernel import digit_histogram_ranks_tiles
from repro.kernels.radix_sort.ref import digit_histogram_ranks_ref

# --------------------------------------------------------------------------
# hash_partition radix kernel
# --------------------------------------------------------------------------


@pytest.mark.parametrize("n,parts", [
    (64, 4), (1000, 7), (2048, 16), (4096, 64), (5000, 3), (8192, 256),
])
def test_radix_interpret_matches_ref(n, parts):
    rng = np.random.default_rng(n * 31 + parts)
    pid = jnp.asarray(rng.integers(0, parts, n).astype(np.int32))
    h_ref, r_ref = radix_histogram_ranks_ref(pid, parts)
    h_k, r_k = radix_histogram_ranks(pid, parts, impl="pallas_interpret",
                                     tile=1024)
    np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_ref))
    np.testing.assert_array_equal(np.asarray(r_k), np.asarray(r_ref))


@pytest.mark.parametrize("tile", [256, 512, 1024])
def test_radix_tile_boundary_sweep(tile):
    """n not divisible by tile exercises the padded-tail path."""
    rng = np.random.default_rng(tile)
    for n in (tile - 1, tile, tile + 1, 3 * tile + 17):
        pid = jnp.asarray(rng.integers(0, 8, n).astype(np.int32))
        h_ref, r_ref = radix_histogram_ranks_ref(pid, 8)
        h_k, r_k = radix_histogram_ranks(pid, 8, impl="pallas_interpret",
                                         tile=tile)
        np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_ref))
        np.testing.assert_array_equal(np.asarray(r_k), np.asarray(r_ref))


def test_partition_plan_dest_is_stable_grouping():
    rng = np.random.default_rng(0)
    pid_np = rng.integers(0, 5, 300).astype(np.int32)
    hist, dest = partition_plan(jnp.asarray(pid_np), 5, impl="ref")
    hist, dest = np.asarray(hist), np.asarray(dest)
    assert hist.sum() == 300
    # dest is a permutation of [0, 300)
    np.testing.assert_array_equal(np.sort(dest), np.arange(300))
    # rows scattered to dest land grouped by pid, stable within pid
    out = np.empty(300, np.int32)
    out[dest] = pid_np
    offsets = np.cumsum(hist) - hist
    for p in range(5):
        seg = out[offsets[p]: offsets[p] + hist[p]]
        assert (seg == p).all()
        src_rows = np.nonzero(pid_np == p)[0]
        np.testing.assert_array_equal(np.sort(dest[src_rows]),
                                      np.arange(offsets[p],
                                                offsets[p] + hist[p]))


def test_radix_ranks_are_stable():
    pid = jnp.asarray(np.array([2, 0, 2, 2, 0, 1], np.int32))
    _, ranks = radix_histogram_ranks_ref(pid, 3)
    np.testing.assert_array_equal(np.asarray(ranks), [0, 0, 1, 2, 1, 0])


# --------------------------------------------------------------------------
# radix_sort digit kernel + multi-pass ops
# --------------------------------------------------------------------------


@pytest.mark.parametrize("n_tiles,tile,radix_bits,shift", [
    (1, 128, 8, 0), (3, 256, 8, 24), (2, 512, 8, 16), (4, 128, 1, 0),
    (2, 256, 4, 28),
])
def test_digit_kernel_interpret_matches_ref(n_tiles, tile, radix_bits,
                                            shift):
    """Fused digit extraction: interpret-mode kernel == pure-jnp ref per
    tile, over negative words too (arithmetic shift + mask is exact)."""
    rng = np.random.default_rng(n_tiles * 7 + tile + shift)
    words = rng.integers(-2 ** 31, 2 ** 31, n_tiles * tile,
                         dtype=np.int64).astype(np.int32)
    tiles = jnp.asarray(words.reshape(n_tiles, tile))
    h_k, r_k = digit_histogram_ranks_tiles(tiles, shift, radix_bits,
                                           interpret=True)
    for t in range(n_tiles):
        h_ref, r_ref = digit_histogram_ranks_ref(tiles[t], shift,
                                                 radix_bits)
        np.testing.assert_array_equal(np.asarray(h_k)[t],
                                      np.asarray(h_ref))
        np.testing.assert_array_equal(np.asarray(r_k)[t],
                                      np.asarray(r_ref))


@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
def test_radix_permutation_matches_lax_sort(impl):
    """The multi-pass engine is bit-identical to a stable lax.sort over
    (validity, keys, iota) — int32 + float32 keys, with a small tile so
    the interpret leg exercises the real kernel + cross-tile scan."""
    rng = np.random.default_rng(0)
    for n, nval in ((7, 7), (64, 50), (130, 128), (97, 0)):
        ik = jnp.asarray(rng.integers(-99, 99, n).astype(np.int32))
        fk = jnp.asarray((rng.integers(-6, 7, n) * 0.25)
                         .astype(np.float32))
        invalid = jnp.arange(n) >= nval
        iota = jnp.arange(n, dtype=jnp.int32)
        want = jax.lax.sort((invalid.astype(jnp.int32), ik, fk, iota),
                            num_keys=3, is_stable=True)[-1]
        got = radix_permutation((ik, fk), invalid, impl=impl, tile=32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"{impl} n={n}")


@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
def test_stable_partition_perm_is_boolean_argsort(impl):
    rng = np.random.default_rng(3)
    for n in (5, 64, 200):
        keep = jnp.asarray(rng.random(n) < 0.4)
        want = jnp.argsort(jnp.logical_not(keep), stable=True)
        got = stable_partition_perm(keep, impl=impl, tile=32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("parts", [3, 512, 2000])
def test_grouped_ranks_matches_single_pass_ref(parts):
    """Any partition count — including past MAX_RADIX_BUCKETS where the
    slab grouping uses this instead of the (n, P) one-hot."""
    rng = np.random.default_rng(parts)
    pid = jnp.asarray(rng.integers(0, parts, 700).astype(np.int32))
    h_ref, r_ref = radix_histogram_ranks_ref(pid, parts)
    for impl in ("ref", "pallas_interpret"):
        h, r = grouped_ranks(pid, parts, impl=impl, tile=256)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(h_ref))
        np.testing.assert_array_equal(np.asarray(r), np.asarray(r_ref))


def test_fused_bucket_ranks_interpret_matches_ref():
    """The fused single-pass bucketing kernel (hash + one-hot histogram +
    stable ranks in one sweep) is bit-identical between the pure-jnp ref
    and the Pallas kernel in interpret mode — small tile so the interpret
    leg exercises the real kernel plus the cross-tile scan, with padding
    (n not a tile multiple) and invalid tail rows."""
    from repro.kernels.fused_bucketing import (fused_bucket_ranks,
                                               fused_bucket_ranks_ref)
    rng = np.random.default_rng(11)
    for n, nval, B in ((7, 7, 4), (130, 100, 16), (97, 0, 8)):
        bits = (jnp.asarray(rng.integers(-99, 99, n).astype(np.int32)),
                jnp.asarray(rng.integers(0, 5, n).astype(np.int32)))
        valid = jnp.arange(n) < nval
        want = fused_bucket_ranks_ref(bits, valid, B)
        got = fused_bucket_ranks(bits, valid, B, impl="pallas_interpret",
                                 tile=32)
        for w, g, name in zip(want, got, ("bid", "hist", "ranks")):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w),
                err_msg=f"{name} n={n} nval={nval} B={B}")


# --------------------------------------------------------------------------
# bucketing: two-pass (histogram, then size) bucket planner
# --------------------------------------------------------------------------


def _skewed_keys(rng, n, heavy=0.6):
    return np.where(rng.random(n) < heavy, 3,
                    rng.integers(0, 40, n)).astype(np.int32)


def test_plan_bucket_sizes_covers_actual_max_load():
    rng = np.random.default_rng(0)
    keys = _skewed_keys(rng, 600)
    B, C = bucketing.plan_bucket_sizes([keys])
    heavy = int((keys == 3).sum())
    assert C >= heavy                       # the hot bucket fits entirely
    assert C % 8 == 0 and B == bucketing.default_bucket_count(600)
    # explicit bucket count is respected
    B2, C2 = bucketing.plan_bucket_sizes([keys], num_buckets=16)
    assert B2 == 16 and C2 >= heavy
    # empty keys -> minimal slab
    Be, Ce = bucketing.plan_bucket_sizes([np.zeros(0, np.int32)])
    assert Ce == 8 and Be >= 1


def test_plan_headroom_survives_extra_hot_duplicates():
    """The default headroom (1.25x observed max load) must keep a plan
    valid when it's *reused* on slightly different keys — here one extra
    duplicate of the hottest key (the next chunk of a stream)."""
    rng = np.random.default_rng(7)
    keys = _skewed_keys(rng, 600)
    B, C = bucketing.plan_bucket_sizes([keys])
    vals, counts = np.unique(keys, return_counts=True)
    hottest = vals[np.argmax(counts)]
    aug = np.append(keys, hottest).astype(np.int32)
    bid = np.asarray(bucketing.bucket_ids(
        (bucketing.key_bits(jnp.asarray(aug)),), B))
    assert int(np.bincount(bid, minlength=B).max()) <= C
    # exact sizing remains available for callers that want it
    _, C0 = bucketing.plan_bucket_sizes([keys], headroom=1.0)
    assert C0 <= C


def test_planner_makes_skewed_groupby_overflow_free(rng):
    """Above EXACT_SLAB_CAP with heavy key skew: the uniform auto-sizing
    heuristic overflows its hottest bucket (rows dropped and counted);
    the two-pass planner — used automatically for concrete keys — sizes
    the slab to the real load and the counter stays zero."""
    from repro.core import local_ops as L
    from repro.core.table import Table

    n = 600
    assert n > bucketing.EXACT_SLAB_CAP
    keys = _skewed_keys(rng, n)
    data = {"k": keys, "v": rng.integers(-50, 50, n).astype(np.float32)}
    t = Table.from_dict(data)
    B = bucketing.default_bucket_count(n)
    heuristic = {"num_buckets": B,
                 "bucket_capacity": max(8, -(-n // B) * 4)}
    _, over = L.groupby_aggregate(t, ["k"], {"v": "sum"}, impl="hash",
                                  return_overflow=True, **heuristic)
    assert int(over) > 0                     # the open ROADMAP failure
    out, over = L.groupby_aggregate(t, ["k"], {"v": "sum"}, impl="hash",
                                    return_overflow=True)
    assert int(over) == 0                    # planner-backed auto-sizing
    want = L.groupby_aggregate(t, ["k"], {"v": "sum"}, impl="sort")
    got, ref = out.to_numpy(), want.to_numpy()
    for c in ref:
        np.testing.assert_array_equal(got[c], ref[c], err_msg=c)
    # dedup rides the same planner
    _, over = L.drop_duplicates(t, ["k"], impl="hash",
                                return_overflow=True)
    assert int(over) == 0


def test_planner_makes_skewed_join_overflow_free(rng):
    from repro.core import local_ops as L
    from repro.core.table import Table

    n = 600
    keys = np.where(rng.random(n) < 0.3, 3,
                    rng.integers(0, 5000, n)).astype(np.int32)
    lt = Table.from_dict({"k": keys,
                          "lv": np.arange(n, dtype=np.float32)})
    rt = Table.from_dict({"k": keys[::-1].copy(),
                          "rv": np.arange(n, dtype=np.float32)})
    out_cap = 80_000
    hj, over = L.join(lt, rt, left_on=["k"], out_capacity=out_cap,
                      impl="hash", return_overflow=True)
    assert int(over) == 0                    # planner-backed auto-sizing
    sm = L.join(lt, rt, left_on=["k"], out_capacity=out_cap,
                impl="sortmerge")
    assert int(hj.nvalid) == int(sm.nvalid)
    for c in sm.names:
        np.testing.assert_array_equal(
            np.asarray(hj.columns[c])[:int(hj.nvalid)],
            np.asarray(sm.columns[c])[:int(sm.nvalid)], err_msg=c)


# --------------------------------------------------------------------------
# hash_join bucketed probe kernel
# --------------------------------------------------------------------------


def _probe_slabs(n_buckets, num_keys, probe_cap, chain_cap, seed,
                 key_range=6, occ_p=0.8):
    rng = np.random.default_rng(seed)
    pbits = rng.integers(0, key_range,
                         (n_buckets, num_keys, probe_cap)).astype(np.int32)
    bbits = rng.integers(0, key_range,
                         (n_buckets, num_keys, chain_cap)).astype(np.int32)
    pocc = (rng.random((n_buckets, probe_cap)) < occ_p).astype(np.int32)
    bocc = (rng.random((n_buckets, chain_cap)) < occ_p).astype(np.int32)
    return tuple(map(jnp.asarray, (pbits, pocc, bbits, bocc)))


@pytest.mark.parametrize("B,K,Lc,C", [
    (1, 1, 8, 8), (4, 1, 16, 32), (8, 2, 32, 16), (16, 3, 64, 64),
    (3, 2, 128, 8),
])
def test_bucket_probe_interpret_matches_ref(B, K, Lc, C):
    pbits, pocc, bbits, bocc = _probe_slabs(B, K, Lc, C, B * 131 + Lc)
    c_ref, r_ref = bucket_probe_ref(pbits, pocc, bbits, bocc)
    c_k, r_k = bucket_probe_buckets(pbits, pocc, bbits, bocc,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_ref))
    np.testing.assert_array_equal(np.asarray(r_k), np.asarray(r_ref))


def test_bucket_probe_ranks_are_within_row_match_order():
    # one bucket, keys [5,7], chain [7,5,7,9,7]: row 0 matches slots 2 with
    # ranks 0,1 ... hand-checked
    pbits = jnp.asarray(np.array([[[5, 7]]], np.int32))
    bbits = jnp.asarray(np.array([[[7, 5, 7, 9, 7]]], np.int32))
    pocc = jnp.ones((1, 2), jnp.int32)
    bocc = jnp.ones((1, 5), jnp.int32)
    counts, rank = bucket_probe_ref(pbits, pocc, bbits, bocc)
    np.testing.assert_array_equal(np.asarray(counts), [[1, 3]])
    np.testing.assert_array_equal(np.asarray(rank)[0],
                                  [[-1, 0, -1, -1, -1],
                                   [0, -1, 1, -1, 2]])


def test_bucket_probe_ignores_unoccupied_slots():
    pbits = jnp.asarray(np.array([[[1, 1]]], np.int32))
    bbits = jnp.asarray(np.array([[[1, 1, 1]]], np.int32))
    pocc = jnp.asarray(np.array([[1, 0]], np.int32))
    bocc = jnp.asarray(np.array([[1, 0, 1]], np.int32))
    counts, rank = bucket_probe_ref(pbits, pocc, bbits, bocc)
    np.testing.assert_array_equal(np.asarray(counts), [[2, 0]])
    np.testing.assert_array_equal(np.asarray(rank)[0, 0], [0, -1, 1])


# --------------------------------------------------------------------------
# hash_semi bucketed membership kernel
# --------------------------------------------------------------------------


@pytest.mark.parametrize("B,K,Lc,C", [
    (1, 1, 8, 8), (4, 1, 16, 32), (8, 2, 32, 16), (16, 3, 64, 64),
    (3, 2, 128, 8),
])
def test_bucket_member_interpret_matches_ref(B, K, Lc, C):
    from repro.kernels.hash_semi.kernel import bucket_member_buckets
    from repro.kernels.hash_semi.ref import bucket_member_ref

    pbits, pocc, bbits, bocc = _probe_slabs(B, K, Lc, C, B * 173 + Lc)
    m_ref = bucket_member_ref(pbits, pocc, bbits, bocc)
    m_k = bucket_member_buckets(pbits, pocc, bbits, bocc, interpret=True)
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_ref))


def test_bucket_member_ignores_unoccupied_slots():
    from repro.kernels.hash_semi.ref import bucket_member_ref

    # probe slot 1 is empty -> never a member even though its bits match;
    # build slot 1 is empty -> key 2 has no occupied build match
    pbits = jnp.asarray(np.array([[[1, 1, 2]]], np.int32))
    bbits = jnp.asarray(np.array([[[1, 2, 3]]], np.int32))
    pocc = jnp.asarray(np.array([[1, 0, 1]], np.int32))
    bocc = jnp.asarray(np.array([[1, 0, 1]], np.int32))
    member = bucket_member_ref(pbits, pocc, bbits, bocc)
    np.testing.assert_array_equal(np.asarray(member), [[1, 0, 0]])


def test_bucket_member_requires_all_key_planes_equal():
    from repro.kernels.hash_semi.ref import bucket_member_ref

    # two key columns: probes (1,2),(5,2) vs builds (1,3),(4,2) — a
    # half-matching key pair is NOT a member; builds (4,3),(1,2) then
    # match probe (1,2) only
    pbits = jnp.asarray(np.array([[[1, 5], [2, 2]]], np.int32))
    bbits = jnp.asarray(np.array([[[1, 4], [3, 2]]], np.int32))
    pocc = jnp.ones((1, 2), jnp.int32)
    bocc = jnp.ones((1, 2), jnp.int32)
    member = bucket_member_ref(pbits, pocc, bbits, bocc)
    np.testing.assert_array_equal(np.asarray(member), [[0, 0]])
    bbits2 = jnp.asarray(np.array([[[4, 1], [3, 2]]], np.int32))
    member2 = bucket_member_ref(pbits, pocc, bbits2, bocc)
    np.testing.assert_array_equal(np.asarray(member2), [[1, 0]])


# --------------------------------------------------------------------------
# flash attention kernel
# --------------------------------------------------------------------------

ATTN_SWEEP = [
    # (B, Hq, Hkv, Sq, Skv, D, causal)
    (1, 1, 1, 128, 128, 64, True),
    (2, 4, 4, 128, 128, 64, True),          # MHA
    (2, 4, 2, 128, 128, 64, True),          # GQA group=2
    (1, 8, 1, 256, 256, 128, True),         # MQA
    (1, 2, 2, 128, 128, 64, False),         # bidirectional
    (1, 4, 2, 128, 256, 64, True),          # Sq < Skv right-aligned causal
    (1, 2, 1, 384, 384, 64, True),          # 3 q-blocks x 3 kv-blocks
]


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D,causal", ATTN_SWEEP)
def test_flash_attention_interpret_matches_ref(B, Hq, Hkv, Sq, Skv, D,
                                               causal):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(Sq + D), 3)
    q = jax.random.normal(k1, (B, Hq, Sq, D), jnp.float32)
    k = jax.random.normal(k2, (B, Hkv, Skv, D), jnp.float32)
    v = jax.random.normal(k3, (B, Hkv, Skv, D), jnp.float32)
    ref = attention_ref(q, k, v, causal=causal)
    got = flash_attention(q, k, v, causal=causal, impl="pallas_interpret",
                          bq=128, bk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(k1, (1, 2, 128, 64), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (1, 2, 128, 64), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (1, 2, 128, 64), jnp.float32).astype(dtype)
    ref = attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, impl="pallas_interpret",
                          bq=128, bk=128)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bq,bk", [(64, 64), (64, 128), (128, 64)])
def test_flash_attention_block_shapes(bq, bk):
    """Output must be block-shape independent."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(k1, (1, 2, 256, 64), jnp.float32)
    k = jax.random.normal(k2, (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(k3, (1, 2, 256, 64), jnp.float32)
    ref = attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, impl="pallas_interpret",
                          bq=bq, bk=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# mamba selective-scan kernel
# --------------------------------------------------------------------------

SCAN_SWEEP = [
    # (B, S, E, N, be, chunk)
    (1, 64, 32, 8, 32, 32),
    (2, 128, 64, 16, 32, 64),
    (1, 256, 128, 16, 128, 128),
    (2, 256, 64, 16, 64, 256),            # chunk == S (single step)
    (1, 512, 32, 8, 32, 128),             # 4 sequential chunks
]


def _scan_inputs(B, S, E, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (B, S, E), jnp.float32)
    delta = jax.nn.softplus(jax.random.normal(ks[1], (B, S, E)))
    A = -jnp.exp(jax.random.normal(ks[2], (E, N)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, S, N), jnp.float32)
    D = jax.random.normal(ks[5], (E,), jnp.float32)
    return x, delta, A, Bm, Cm, D


@pytest.mark.parametrize("B,S,E,N,be,chunk", SCAN_SWEEP)
def test_selective_scan_interpret_matches_ref(B, S, E, N, be, chunk):
    x, delta, A, Bm, Cm, D = _scan_inputs(B, S, E, N, seed=S + E)
    ref, _ = selective_scan_ref(x, delta, A, Bm, Cm, D)
    got = selective_scan(x, delta, A, Bm, Cm, D, impl="pallas_interpret",
                         be=be, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_selective_scan_state_carries_across_chunks():
    """Same inputs, different chunking -> identical output (state carry)."""
    x, delta, A, Bm, Cm, D = _scan_inputs(1, 256, 32, 8, seed=11)
    a = selective_scan(x, delta, A, Bm, Cm, D, impl="pallas_interpret",
                       be=32, chunk=64)
    b = selective_scan(x, delta, A, Bm, Cm, D, impl="pallas_interpret",
                       be=32, chunk=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_selective_scan_bf16_inputs():
    x, delta, A, Bm, Cm, D = _scan_inputs(1, 128, 32, 8, seed=5)
    ref, _ = selective_scan_ref(x, delta, A, Bm, Cm, D)
    got = selective_scan(x.astype(jnp.bfloat16), delta, A, Bm, Cm, D,
                         impl="pallas_interpret", be=32, chunk=64)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)
