"""Perf-lever flags: baseline (flags off) and optimized (flags on)
lowerings both compile, and the optimized lowering is numerically
equivalent on a real forward/backward (single device, small model)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import TrainSettings
from repro.models import model as M
from repro.optim import adamw

FLAGS_OFF = dict(gqa_shard_opt=False, bf16_weight_cast=False,
                 grad_2d_accum=False, ssm_shard_opt=False,
                 mlp_shard_opt=False)


def _with_flags(cfg, **flags):
    return dataclasses.replace(
        cfg, train=dataclasses.replace(cfg.train, **flags))


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen3-moe-235b-a22b",
                                  "jamba-1.5-large-398b"])
def test_flags_off_equals_flags_on_single_device(arch):
    """Without a mesh the flags only toggle no-op constraints/casts that
    are numerically identical (weights are cast at use anyway)."""
    cfg_on = get_reduced(arch)
    cfg_off = _with_flags(cfg_on, **FLAGS_OFF)
    params = M.init_params(jax.random.PRNGKey(0), cfg_on)
    opt_cfg = adamw.AdamWConfig()
    opt = adamw.init(params, opt_cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg_on.vocab, (2, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg_on.vocab, (2, 16)),
                                   jnp.int32)}
    _, _, m_on = jax.jit(M.make_train_step(cfg_on, None, opt_cfg))(
        params, opt, batch)
    _, _, m_off = jax.jit(M.make_train_step(cfg_off, None, opt_cfg))(
        params, opt, batch)
    np.testing.assert_allclose(float(m_on["loss"]), float(m_off["loss"]),
                               rtol=1e-5)


def test_both_lowerings_compile_on_debug_mesh():
    from repro.launch import specs as SP
    from repro.launch.mesh import make_debug_mesh
    from repro.models.sharding import make_policy
    from jax.sharding import PartitionSpec as P

    mesh = make_debug_mesh(1, 1)
    cfg_on = get_reduced("granite-3-2b")
    for cfg in (cfg_on, _with_flags(cfg_on, **FLAGS_OFF)):
        policy = make_policy(mesh, cfg.train.sharding)
        opt_cfg = adamw.AdamWConfig()
        params = SP.param_specs(cfg, policy)
        opt = SP.opt_state_specs(cfg, policy, params, opt_cfg)
        batch = {
            "tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32,
                                           sharding=policy.named(P())),
            "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32,
                                           sharding=policy.named(P())),
        }
        step = M.make_train_step(cfg, policy, opt_cfg)
        compiled = jax.jit(step).lower(params, opt, batch).compile()
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, list) else cost
        assert cost.get("flops", 0) > 0
