"""Trip-count-aware HLO cost model tests (repro.roofline.hlo_cost)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.context import shard_map
from repro.roofline.hlo_cost import analyze_hlo_text, parse_module


def _text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    x = jnp.zeros((256, 256), jnp.float32)
    w = jnp.zeros((256, 256), jnp.float32)
    c = analyze_hlo_text(_text(lambda x, w: x @ w, x, w))
    want = 2 * 256 ** 3
    assert abs(c.flops - want) / want < 0.01


def test_scan_multiplies_by_trip_count():
    x = jnp.zeros((128, 128), jnp.float32)
    w = jnp.zeros((128, 128), jnp.float32)

    def one(x, w):
        return x @ w

    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y

    c1 = analyze_hlo_text(_text(one, x, w))
    c12 = analyze_hlo_text(_text(scanned, x, w))
    np.testing.assert_allclose(c12.flops / c1.flops, 12.0, rtol=0.05)


def test_nested_scan_multiplies_both_levels():
    x = jnp.zeros((64, 64), jnp.float32)
    w = jnp.zeros((64, 64), jnp.float32)

    def nested(x, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    c1 = analyze_hlo_text(_text(lambda x, w: x @ w, x, w))
    cn = analyze_hlo_text(_text(nested, x, w))
    np.testing.assert_allclose(cn.flops / c1.flops, 12.0, rtol=0.1)


def test_dynamic_slice_billed_at_window():
    """Reading one (128,128) slice of a (64,128,128) stack per scan step
    must bill ~the window, not the whole stack."""
    stack = jnp.zeros((64, 128, 128), jnp.float32)
    x = jnp.zeros((128, 128), jnp.float32)

    def f(stack, x):
        def body(c, i):
            w = jax.lax.dynamic_slice_in_dim(stack, i, 1, 0)[0]
            return c @ w, None
        y, _ = jax.lax.scan(body, x, jnp.arange(64))
        return y

    c = analyze_hlo_text(_text(f, stack, x))
    window = 128 * 128 * 4
    # per-iter traffic should be O(few windows), not O(stack)
    per_iter = c.bytes / 64
    assert per_iter < 12 * window, (per_iter, window)


def test_collectives_counted_with_trip_multiplier():
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))

    def scanned(x):
        def body(c, _):
            return jax.lax.psum(c, "d"), None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    f = shard_map(scanned, mesh=mesh, in_specs=P(), out_specs=P())
    c = analyze_hlo_text(_text(f, jnp.zeros((8, 8))))
    # single-device psum may fold away; accept 0 or 5 but never 1
    n = c.coll_counts.get("all-reduce", 0)
    assert n in (0, 5), n


def test_parse_module_structure():
    x = jnp.zeros((32, 32), jnp.float32)
    comps = parse_module(_text(lambda x: jnp.tanh(x @ x), x))
    assert any(n.startswith("main") for n in comps)
    main = next(c for n, c in comps.items() if n.startswith("main"))
    assert len(main.ops) >= 1
    assert main.symbols                     # symbol table populated


def test_wire_factor_detects_bf16_psum():
    from repro.roofline.hlo_cost import (_wire_factor, parse_module,
                                         _COLLECTIVES)
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    f = shard_map(lambda v: jax.lax.psum(v, "d"), mesh=mesh,
                  in_specs=P(), out_specs=P())
    text = jax.jit(f).lower(jnp.zeros((64, 64), jnp.bfloat16)) \
        .compile().as_text()
    comps = parse_module(text)
    found = []
    for comp in comps.values():
        for op in comp.ops:
            base = op.opcode[:-6] if op.opcode.endswith("-start") \
                else op.opcode
            if base in _COLLECTIVES:
                found.append(_wire_factor(op, comp, comps))
    # single-device psum may be elided; if present it must be billed bf16
    for w in found:
        assert w == 0.5, found
