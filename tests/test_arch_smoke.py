"""Per-architecture smoke tests (assignment deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED config
of the same family and run one forward/train step on CPU, asserting output
shapes and no NaNs.  (The FULL configs are exercised only via the dry-run.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cells_for, get_config, \
    get_reduced
from repro.models import model as M
from repro.optim import adamw

B, S = 2, 32


def _batch(cfg, B=B, S=S, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)),
            jnp.bfloat16)
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S // cfg.enc_len_ratio, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_reduced(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.AdamWConfig()
    opt = adamw.init(params, opt_cfg)
    step = jax.jit(M.make_train_step(cfg, None, opt_cfg))
    p2, o2, metrics = step(params, opt, _batch(cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss)
    assert loss > 0
    assert int(o2["step"]) == 1
    # params actually moved
    d0 = jax.tree_util.tree_leaves(params)[0]
    d1 = jax.tree_util.tree_leaves(p2)[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_loss_decreases(arch):
    """Two steps on the same batch must reduce the loss (sanity that the
    whole grad path is wired for every family)."""
    cfg = get_reduced(arch)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0)
    opt = adamw.init(params, opt_cfg)
    step = jax.jit(M.make_train_step(cfg, None, opt_cfg))
    batch = _batch(cfg, seed=1)
    losses = []
    for _ in range(3):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_prefill_then_decode(arch):
    """Prefill emits caches; serve_step consumes them; logits stay finite
    and shaped (B, V)."""
    cfg = get_reduced(arch)
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    decode_len = S + 4
    prefill = jax.jit(M.make_prefill(cfg, None, decode_len=decode_len))
    serve = jax.jit(M.make_serve_step(cfg, None))
    batch = _batch(cfg)
    batch.pop("labels")
    logits, caches = prefill(params, batch)
    V = cfg.padded_vocab()
    assert logits.shape == (B, V)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    for i in range(2):
        logits, caches = serve(params, caches, tok, jnp.int32(S + i))
        assert logits.shape == (B, V)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]


def test_decode_matches_teacher_forcing_dense():
    """Strong consistency: greedy decode logits == full-sequence forward
    logits at the same positions (dense arch; bf16 tolerance)."""
    cfg = get_reduced("granite-3-2b")
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)

    # full forward logits at the last position
    opts = M.opts_from_cfg(cfg)
    x, _, _, _ = M.backbone(params, cfg, {"tokens": toks}, None, opts)
    from repro.models import layers as Ly
    full_logits = Ly.logits_out(
        params.get("lm_head"), x,
        tied_embed=params["embed"] if cfg.tie_embeddings else None)

    # prefill on first 7 tokens, decode token 8
    decode_len = 12
    prefill = M.make_prefill(cfg, None, decode_len=decode_len)
    serve = M.make_serve_step(cfg, None)
    _, caches = prefill(params, {"tokens": toks[:, :7]})
    step_logits, _ = serve(params, caches, toks[:, 7:8], jnp.int32(7))
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits[:, 7]),
                               rtol=3e-2, atol=3e-2)


def test_decode_matches_teacher_forcing_ssm():
    cfg = get_reduced("falcon-mamba-7b")
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    opts = M.opts_from_cfg(cfg)
    x, _, _, _ = M.backbone(params, cfg, {"tokens": toks}, None, opts)
    from repro.models import layers as Ly
    full_logits = Ly.logits_out(
        params.get("lm_head"), x,
        tied_embed=params["embed"] if cfg.tie_embeddings else None)
    prefill = M.make_prefill(cfg, None, decode_len=12)
    serve = M.make_serve_step(cfg, None)
    _, caches = prefill(params, {"tokens": toks[:, :7]})
    step_logits, _ = serve(params, caches, toks[:, 7:8], jnp.int32(7))
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits[:, 7]),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """Config registry carries the exact published sizes."""
    cfg = get_config(arch)
    spec = {
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 0, 151936),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 0, 49155),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == spec


def test_param_counts_in_published_ballpark():
    """Analytic param counts should be near the advertised sizes."""
    expect = {
        "qwen1.5-110b": 111e9,
        "minitron-4b": 4.8e9,        # embeddings dominate (256k vocab)
        "mistral-large-123b": 123e9,
        "granite-3-2b": 2.6e9,
        "qwen3-moe-235b-a22b": 235e9,
        "falcon-mamba-7b": 7.3e9,
        "jamba-1.5-large-398b": 398e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.75 * n < got < 1.30 * n, (arch, got, n)


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    active = cfg.active_param_count()
    assert 15e9 < active < 30e9          # A22B
    assert active < cfg.param_count() / 5


def test_cells_for_respects_skips():
    # ssm/hybrid run long_500k; pure-attention archs skip it
    assert "long_500k" in cells_for("falcon-mamba-7b")
    assert "long_500k" in cells_for("jamba-1.5-large-398b")
    assert "long_500k" not in cells_for("qwen1.5-110b")
    for arch in ARCH_IDS:
        assert "train_4k" in cells_for(arch)
        assert "decode_32k" in cells_for(arch)


def test_shapes_registry():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].kind == "prefill"
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].seq_len == 524_288
