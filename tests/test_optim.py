"""Optimizer + compression unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw
from repro.optim.compression import _quant_chunks, init_residuals


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=200, min_lr_ratio=1.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    target = jnp.asarray([1.0, 2.0])
    state = adamw.init(params, cfg)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adamw.update(params, g, state, cfg)

    for _ in range(150):
        params, state, _ = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(target), atol=1e-2)


def test_schedule_warmup_and_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lr0 = float(adamw.schedule(cfg, jnp.int32(0)))
    lr5 = float(adamw.schedule(cfg, jnp.int32(5)))
    lr10 = float(adamw.schedule(cfg, jnp.int32(10)))
    lr100 = float(adamw.schedule(cfg, jnp.int32(100)))
    assert lr0 == 0.0
    assert abs(lr5 - 0.5) < 1e-6
    assert abs(lr10 - 1.0) < 1e-6
    assert abs(lr100 - 0.1) < 1e-3      # decays to min_lr_ratio * lr


def test_grad_clipping_bounds_update():
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0,
                            warmup_steps=0, min_lr_ratio=1.0)
    params = {"w": jnp.zeros((4,))}
    state = adamw.init(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw.update(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5   # pre-clip norm is reported


def test_decay_mask_skips_norm_scales():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=0,
                            min_lr_ratio=1.0)
    params = {"dense": {"w": jnp.ones((2,))},
              "norm": {"scale": jnp.ones((2,))}}
    state = adamw.init(params, cfg)
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
    p2, _, _ = adamw.update(params, zero_g, state, cfg)
    # w decays toward 0; scale does not
    assert float(p2["dense"]["w"][0]) < 1.0
    np.testing.assert_allclose(np.asarray(p2["norm"]["scale"]), 1.0)


def test_moment_dtype_respected():
    cfg = adamw.AdamWConfig(moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((2,), jnp.float32)}
    state = adamw.init(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((2,))}
    _, s2, _ = adamw.update(params, g, state, cfg)
    assert s2["m"]["w"].dtype == jnp.bfloat16


def test_quantization_error_bounded():
    rng = np.random.default_rng(0)
    parts = jnp.asarray(rng.normal(size=(4, 256)).astype(np.float32))
    q, scale = _quant_chunks(parts)
    deq = np.asarray(q, np.float32) * np.asarray(scale)
    err = np.abs(deq - np.asarray(parts))
    # max error is half a quantization bin per chunk
    bins = np.asarray(scale)
    assert (err <= bins / 2 + 1e-7).all()
    assert q.dtype == jnp.int8


def test_init_residuals_zero():
    params = {"a": jnp.ones((3,)), "b": {"c": jnp.ones((2, 2))}}
    res = init_residuals(params)
    for leaf in jax.tree_util.tree_leaves(res):
        assert float(jnp.sum(jnp.abs(leaf))) == 0.0
        assert leaf.dtype == jnp.float32
