"""OrderBy backend conformance suite (radix == xla, == pandas oracle).

The two local sort backends promise *drop-in bit-identical* output — same
rows, same order, same dtypes, including the stable order of equal keys
and the padding region (contract 1 in kernels/README.md).  This suite
pins that contract over key distributions x multi-key/ascending-mix
specs x kernel impls, checks the radix path's jaxpr carries **no
``sort`` primitive** (the acceptance bar: OrderBy without a sort), checks
the 1-bit compaction fast path (``compact``/``select``) is bit-identical
to the stable boolean argsort it replaced, and runs the distributed
sample-sort at world sizes 1/2/4 in subprocesses with forced host
devices (``tests/dist/sort_conformance.py``), including a shard-skew
regression at world 4.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernel_backend, local_ops as L
from repro.core.table import Table

from oracles import np_sort_values

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)

ROWS = 48

DISTS = ["uniform", "skewed", "allequal", "alldistinct", "empty"]

# (by, ascending): single/multi key, per-key ascending mixes, int+float
KEYSPECS = [
    (["k"], True),
    (["k"], False),
    (["k", "f"], [True, False]),
    (["f", "k"], [False, True]),
    (["f", "k", "rid"], True),
]


def make_data(dist: str, rng) -> dict:
    if dist == "uniform":
        k = rng.integers(-12, 12, ROWS)
    elif dist == "skewed":                     # one heavy key + sparse tail
        k = np.where(rng.random(ROWS) < 0.6, 3,
                     rng.integers(-40, 40, ROWS))
    elif dist == "allequal":                   # ties only: pure stability
        k = np.full(ROWS, 7)
    elif dist == "alldistinct":
        k = rng.permutation(ROWS) - ROWS // 2
    else:                                      # empty
        k = np.zeros(0, np.int64)
    n = len(k)
    return {"k": k.astype(np.int32),
            # duplicate-heavy float key off an exact grid, negatives incl.
            "f": (rng.integers(-4, 5, n) * 0.5).astype(np.float32),
            "v": rng.normal(size=n).astype(np.float32),
            "rid": np.arange(n, dtype=np.int32)}   # pins tie stability


def run_both(t: Table, by, ascending, kernel_impl="ref"):
    x = L.sort_values(t, by, ascending, impl="xla")
    r = L.sort_values(t, by, ascending, impl="radix",
                      kernel_impl=kernel_impl)
    assert int(x.nvalid) == int(r.nvalid) == int(t.nvalid)
    return x, r


def assert_bit_identical(x: Table, r: Table, msg=""):
    """Full-column compare: valid rows AND the padding region agree."""
    assert set(x.names) == set(r.names), msg
    for c in x.names:
        a, b = np.asarray(x.columns[c]), np.asarray(r.columns[c])
        assert a.dtype == b.dtype, f"{msg} col={c} dtype"
        np.testing.assert_array_equal(a, b, err_msg=f"{msg} col={c}")


@pytest.mark.parametrize("dist", DISTS)
@pytest.mark.parametrize("spec", KEYSPECS,
                         ids=["k_asc", "k_desc", "kf_mix", "fk_mix",
                              "three_key"])
@pytest.mark.parametrize("kernel_impl", ["ref", "pallas_interpret"])
def test_local_backends_identical(dist, spec, kernel_impl, rng):
    by, ascending = spec
    data = make_data(dist, rng)
    t = Table.from_dict(data, capacity=max(len(data["k"]), 1) + 5)
    x, r = run_both(t, by, ascending, kernel_impl)
    assert_bit_identical(x, r, f"{dist}/{by}")
    want = np_sort_values(data, by, ascending)
    got = r.to_numpy()
    for c in want:   # stable pandas-semantics order, rid pins ties
        np.testing.assert_array_equal(
            got[c], want[c].astype(got[c].dtype),
            err_msg=f"{dist}/{by} vs oracle col={c}")


def test_above_tile_runs_real_kernel(rng):
    """n past the pallas tile boundary: the interpret-mode digit kernel
    (not the ref fallback) must still be bit-identical."""
    n = 1400
    data = {"k": rng.integers(-1000, 1000, n).astype(np.int32),
            "rid": np.arange(n, dtype=np.int32)}
    t = Table.from_dict(data, capacity=n + 13)
    x, r = run_both(t, ["k"], True, "pallas_interpret")
    assert_bit_identical(x, r, "above_tile")


def _jaxpr_primitives(fn, *args):
    prims = set()

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            prims.add(eqn.primitive.name)
            for v in eqn.params.values():
                for x in (v if isinstance(v, (list, tuple)) else (v,)):
                    if hasattr(x, "jaxpr"):
                        walk(x.jaxpr)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return prims


def test_radix_path_contains_no_sort_primitive(rng):
    """The acceptance contract: sort_values(impl='radix') replaces the
    XLA sort entirely — its jaxpr must not contain ``sort``; the xla
    backend, for contrast, does sort."""
    data = make_data("uniform", rng)
    t = Table.from_dict(data, capacity=ROWS + 5)
    prims = _jaxpr_primitives(
        lambda tt: L.sort_values(tt, ["k", "f"], [True, False],
                                 impl="radix"), t)
    assert "sort" not in prims, sorted(prims)
    prims = _jaxpr_primitives(
        lambda tt: L.sort_values(tt, ["k"], impl="xla"), t)
    assert "sort" in prims


def test_compaction_paths_contain_no_sort_primitive(rng):
    """compact/select (and through them dropna etc.) run the radix
    engine's 1-bit pass unconditionally — no sort primitive left."""
    data = make_data("uniform", rng)
    t = Table.from_dict(data, capacity=ROWS + 5)
    prims = _jaxpr_primitives(lambda tt: L.select(tt, tt["k"] > 0), t)
    assert "sort" not in prims, sorted(prims)
    prims = _jaxpr_primitives(lambda tt: L.dropna(tt, ["v"]), t)
    assert "sort" not in prims, sorted(prims)


def test_compact_matches_stable_argsort_reference(rng):
    """The 1-bit fast path is bit-identical to the boolean stable argsort
    compaction it replaced (same rows, same order, padding included)."""
    data = make_data("uniform", rng)
    t = Table.from_dict(data, capacity=ROWS + 7)
    keep = t["k"] > 0
    got = L.compact(t, keep)
    keep_ref = keep & t.valid_mask
    perm = jnp.argsort(jnp.logical_not(keep_ref), stable=True)
    want = t.gather_rows(perm, jnp.sum(keep_ref, dtype=jnp.int32))
    assert int(got.nvalid) == int(want.nvalid)
    assert_bit_identical(want, got, "compact")


def test_env_default_backend(monkeypatch, rng):
    data = make_data("uniform", rng)
    t = Table.from_dict(data, capacity=ROWS)
    monkeypatch.setenv("REPRO_SORT_IMPL", "radix")
    assert kernel_backend.sort_impl() == "radix"
    r = L.sort_values(t, ["k"])
    monkeypatch.setenv("REPRO_SORT_IMPL", "xla")
    x = L.sort_values(t, ["k"])
    assert_bit_identical(x, r, "env dispatch")
    with pytest.raises(ValueError):
        L.sort_values(t, ["k"], impl="nope")


def test_sort_feeds_sort_based_operators(monkeypatch, rng):
    """Operators built on sort_values (dedup, groupby, sortmerge join)
    are backend-invariant end to end."""
    data = make_data("skewed", rng)
    t = Table.from_dict(data, capacity=ROWS + 3)
    outs = {}
    for impl in ("xla", "radix"):
        monkeypatch.setenv("REPRO_SORT_IMPL", impl)
        d = L.drop_duplicates(t, ["k"], impl="sort")
        g = L.groupby_aggregate(t, ["k"], {"v": ["sum", "count"]},
                                impl="sort")
        j = L.join(t, t, left_on=["k"], how="inner",
                   out_capacity=ROWS * ROWS, impl="sortmerge")
        outs[impl] = (d, g, j)
    for a, b in zip(outs["xla"], outs["radix"]):
        assert int(a.nvalid) == int(b.nvalid)
        for c in a.names:
            np.testing.assert_array_equal(
                np.nan_to_num(np.asarray(a.columns[c])[:int(a.nvalid)],
                              nan=-1e9),
                np.nan_to_num(np.asarray(b.columns[c])[:int(b.nvalid)],
                              nan=-1e9), err_msg=c)


@pytest.mark.parametrize("world", [1, 2, 4])
def test_dist_sort_conformance(world):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={world}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable,
         os.path.join(HERE, "dist", "sort_conformance.py"), str(world)],
        env=env, capture_output=True, text=True, timeout=1800)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, f"sort conformance failed (world={world})"
    assert "SORT CONFORMANCE PASSED" in proc.stdout
