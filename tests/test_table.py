"""Table abstraction unit tests: constructors, invariants, pytree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.table import (FLOAT_NULL, INT_NULL, Table, isnull_values,
                              null_like)


def test_from_dict_roundtrip():
    data = {"a": np.arange(5, dtype=np.int64),
            "b": np.linspace(0, 1, 5).astype(np.float64)}
    t = Table.from_dict(data)
    out = t.to_numpy()
    np.testing.assert_array_equal(out["a"], data["a"].astype(np.int32))
    np.testing.assert_allclose(out["b"], data["b"].astype(np.float32),
                               rtol=1e-6)
    assert t.capacity == 5
    assert int(t.nvalid) == 5


def test_from_dict_with_capacity_padding():
    t = Table.from_dict({"a": [1, 2, 3]}, capacity=8)
    assert t.capacity == 8
    assert int(t.nvalid) == 3
    out = t.to_numpy()
    assert len(out["a"]) == 3
    mask = np.asarray(t.valid_mask)
    assert mask.sum() == 3 and mask[:3].all() and not mask[3:].any()


def test_from_dict_rejects_capacity_too_small():
    with pytest.raises(ValueError):
        Table.from_dict({"a": [1, 2, 3]}, capacity=2)


def test_from_dict_rejects_ragged():
    with pytest.raises(ValueError):
        Table.from_dict({"a": [1, 2], "b": [1, 2, 3]})


def test_from_dict_rejects_2d():
    with pytest.raises(ValueError):
        Table.from_dict({"a": np.zeros((2, 2))})


def test_from_dict_rejects_strings():
    with pytest.raises(TypeError):
        Table.from_dict({"a": np.array(["x", "y"])})


def test_bool_becomes_int32():
    t = Table.from_dict({"a": np.array([True, False])})
    assert t.columns["a"].dtype == jnp.int32


def test_pytree_roundtrip_through_jit():
    t = Table.from_dict({"a": [1, 2, 3], "b": [1.0, 2.0, 3.0]}, capacity=4)

    @jax.jit
    def f(tbl: Table) -> Table:
        return tbl.map_column("a", lambda c: c * 2)

    out = f(t)
    assert isinstance(out, Table)
    np.testing.assert_array_equal(out.to_numpy()["a"], [2, 4, 6])
    np.testing.assert_allclose(out.to_numpy()["b"], [1.0, 2.0, 3.0])


def test_pytree_structure_stable():
    t = Table.from_dict({"a": [1], "b": [2]})
    leaves, treedef = jax.tree_util.tree_flatten(t)
    t2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert t2.names == t.names
    np.testing.assert_array_equal(np.asarray(t2.nvalid),
                                  np.asarray(t.nvalid))


def test_to_tensor_zeroes_padding():
    t = Table.from_dict({"x": [1.0, 2.0], "y": [3, 4]}, capacity=4)
    ten = np.asarray(t.to_tensor(["x", "y"]))
    assert ten.shape == (4, 2)
    np.testing.assert_allclose(ten[:2], [[1, 3], [2, 4]])
    np.testing.assert_allclose(ten[2:], 0.0)


def test_gather_rows():
    t = Table.from_dict({"a": [10, 20, 30]})
    g = t.gather_rows(jnp.array([2, 0, 1]), 3)
    np.testing.assert_array_equal(g.to_numpy()["a"], [30, 10, 20])


def test_pad_to_grows_and_refuses_shrink():
    t = Table.from_dict({"a": [1, 2]})
    t2 = t.pad_to(5)
    assert t2.capacity == 5 and int(t2.nvalid) == 2
    with pytest.raises(ValueError):
        t.pad_to(1)


def test_rename_add_prefix_astype():
    t = Table.from_dict({"a": [1], "b": [2.0]})
    assert set(t.rename({"a": "z"}).names) == {"z", "b"}
    assert set(t.add_prefix("p_").names) == {"p_a", "p_b"}
    t2 = t.astype({"a": jnp.float32})
    assert t2.columns["a"].dtype == jnp.float32


def test_null_sentinels():
    ints = jnp.array([1, INT_NULL, 3], jnp.int32)
    floats = jnp.array([1.0, FLOAT_NULL, 3.0], jnp.float32)
    np.testing.assert_array_equal(np.asarray(isnull_values(ints)),
                                  [False, True, False])
    np.testing.assert_array_equal(np.asarray(isnull_values(floats)),
                                  [False, True, False])
    assert np.asarray(isnull_values(null_like(ints))).all()
    assert np.asarray(isnull_values(null_like(floats))).all()


def test_head():
    from repro.core import local_ops as L
    t = Table.from_dict({"a": [1, 2, 3, 4]})
    h = L.head(t, 2)
    np.testing.assert_array_equal(h.to_numpy()["a"], [1, 2])
