"""Shared "pandas" oracles for the operator conformance suites.

Imported both by in-process pytest modules (tests/ is on sys.path via the
conftest mechanism) and by the tests/dist/*.py subprocess workers (which
add this directory to sys.path explicitly).  The aggregation-family
oracles (`np_groupby_aggregate`, `np_drop_duplicates`,
`np_standard_scale`) implement *pandas semantics* — `df.groupby(by,
sort=True).agg(...)`, `df.drop_duplicates(subset)` (keep-first) sorted by
key, population-std `StandardScaler` — and run on real pandas whenever it
is importable, falling back to equivalent numpy when it is not (this
container has no pandas; CI may).  numpy-only otherwise: subprocesses run
without pytest.
"""
import numpy as np

try:
    import pandas as _pd
except ImportError:          # not installed in the CPU container
    _pd = None


def np_join(left: dict, right: dict, how: str) -> dict:
    """Brute-force inner/left join on column 'k' of {'k','lv'} x
    {'k','rv'}; row order matches the engine's contract (left-row-major,
    matches in right original row order; unmatched left rows emit NaN
    right values)."""
    lk, rk = left["k"], right["k"]
    rows = []
    for i in range(len(lk)):
        matches = [j for j in range(len(rk)) if rk[j] == lk[i]]
        if matches:
            rows += [(i, j) for j in matches]
        elif how == "left":
            rows.append((i, None))
    out = {"k": [], "lv": [], "rv": []}
    for i, j in rows:
        out["k"].append(lk[i])
        out["lv"].append(left["lv"][i])
        out["rv"].append(right["rv"][j] if j is not None else np.nan)
    return {k: np.asarray(v) for k, v in out.items()}


def _normalize_aggs(aggs: dict) -> dict:
    return {c: [ops] if isinstance(ops, str) else list(ops)
            for c, ops in aggs.items()}


def np_groupby_aggregate(data: dict, by, aggs: dict) -> dict:
    """GroupBy+Aggregate oracle with pandas semantics: one row per
    distinct key, rows sorted by the ``by`` columns, output columns named
    ``{col}_{agg}`` (count int32, other aggregates float64 — cast before
    exact compares)."""
    by = list(by)
    aggs = _normalize_aggs(aggs)
    if _pd is not None:
        df = _pd.DataFrame({k: np.asarray(v) for k, v in data.items()})
        g = df.groupby(by, sort=True)
        keys = g.size().reset_index()
        out = {k: keys[k].to_numpy() for k in by}
        res = g.agg({c: ops for c, ops in aggs.items()})
        for c, ops in aggs.items():
            for op in ops:
                v = res[(c, op)].to_numpy()
                out[f"{c}_{op}"] = (v.astype(np.int32) if op == "count"
                                    else v.astype(np.float64))
        return out
    keys = list(zip(*[np.asarray(data[k]).tolist() for k in by])) \
        if len(np.asarray(data[by[0]])) else []
    uniq = sorted(set(keys))
    out = {}
    for i, k in enumerate(by):
        out[k] = np.asarray([u[i] for u in uniq],
                            dtype=np.asarray(data[k]).dtype)
    members = {u: [i for i, kk in enumerate(keys) if kk == u]
               for u in uniq}
    for c, ops in aggs.items():
        vals = np.asarray(data[c], dtype=np.float64)
        for op in ops:
            res = []
            for u in uniq:
                sub = vals[members[u]]
                res.append({"sum": sub.sum, "count": lambda s=sub: len(s),
                            "mean": sub.mean, "min": sub.min,
                            "max": sub.max}[op]())
            out[f"{c}_{op}"] = (np.asarray(res, np.int32) if op == "count"
                                else np.asarray(res, np.float64))
    return out


def np_sort_values(data: dict, by, ascending=True) -> dict:
    """OrderBy oracle with pandas semantics: ``df.sort_values(by,
    ascending=..., kind="stable")`` — stable multi-key sort with per-key
    ascending flags; ties keep original row order."""
    by = list(by)
    asc = [ascending] * len(by) if isinstance(ascending, bool) \
        else list(ascending)
    if _pd is not None:
        df = _pd.DataFrame({k: np.asarray(v) for k, v in data.items()})
        df = df.sort_values(by, ascending=asc, kind="stable")
        return {k: df[k].to_numpy() for k in data}
    n = len(np.asarray(data[by[0]]))
    order = np.arange(n)
    # successive stable sorts, least-significant key first (radix style);
    # descending via float64 negation (exact for int32/float32 values)
    for k, a in zip(reversed(by), reversed(asc)):
        col = np.asarray(data[k])[order].astype(np.float64)
        idx = np.argsort(col if a else -col, kind="stable")
        order = order[idx]
    return {k: np.asarray(v)[order] for k, v in data.items()}


def np_drop_duplicates(data: dict, subset) -> dict:
    """Unique oracle with pandas semantics: ``drop_duplicates(subset)``
    (keep the first occurrence's full row) then sorted by the subset key
    columns — the engine's canonical output order."""
    subset = list(subset)
    if _pd is not None:
        df = _pd.DataFrame({k: np.asarray(v) for k, v in data.items()})
        df = df.drop_duplicates(subset=subset).sort_values(subset,
                                                           kind="stable")
        return {k: df[k].to_numpy() for k in data}
    keys = list(zip(*[np.asarray(data[k]).tolist() for k in subset])) \
        if len(np.asarray(data[subset[0]])) else []
    first: dict = {}
    for i, k in enumerate(keys):
        first.setdefault(k, i)
    order = [first[k] for k in sorted(first)]
    return {c: np.asarray(v)[order] for c, v in data.items()}


def _key_rows(data: dict, on):
    """Rows of the ``on`` columns as float64 tuples — the promoted-dtype
    comparison the engine uses (exact for test-scale int32/float32
    values), so an int32 3 and a float32 3.0 are the *same* key while a
    float32 3.7 is not."""
    on = list(on)
    n = len(np.asarray(data[on[0]]))
    return [tuple(float(np.asarray(data[k])[i]) for k in on)
            for i in range(n)]


def np_isin(data: dict, col: str, values: dict, values_col: str):
    """Membership-mask oracle: per row of ``data``, is its ``col`` value
    present among ``values[values_col]`` — compared as float64 (the
    promoted common dtype), pandas ``df[col].isin(vals)`` semantics."""
    vals = {float(v) for v in np.asarray(values[values_col]).tolist()}
    return np.asarray([float(v) in vals
                       for v in np.asarray(data[col]).tolist()])


def np_difference(a: dict, b: dict, on) -> dict:
    """Difference oracle: rows of ``a`` (all occurrences, original row
    order) whose ``on`` key has no match in ``b`` — the engine's stable
    row-compaction contract."""
    bkeys = set(_key_rows(b, on))
    keep = [i for i, k in enumerate(_key_rows(a, on)) if k not in bkeys]
    return {c: np.asarray(v)[keep] for c, v in a.items()}


def np_intersect(a: dict, b: dict, on) -> dict:
    """Intersect oracle: distinct ``on`` keys of ``a`` present in ``b``,
    canonical output — one row per distinct key (keep-first payload),
    sorted by key — matching the engine's dedup contract."""
    bkeys = set(_key_rows(b, on))
    akeys = _key_rows(a, on)
    keep = [i for i, k in enumerate(akeys) if k in bkeys]
    kept = {c: np.asarray(v)[keep] for c, v in a.items()}
    return np_drop_duplicates(kept, on) if keep else \
        {c: np.asarray(v)[:0] for c, v in a.items()}


def np_union(a: dict, b: dict, on) -> dict:
    """Union oracle: concat (``a`` first, so its rows win keep-first ties)
    + drop_duplicates on the ``on`` keys, canonical sorted-by-key
    output."""
    cat = {c: np.concatenate([np.asarray(a[c]), np.asarray(b[c])])
           for c in a}
    return np_drop_duplicates(cat, on)


def np_standard_scale(data: dict, cols) -> dict:
    """StandardScaler oracle: (x - mean) / sqrt(var + 1e-12) per column,
    population variance, float64 accumulation (sklearn/pandas
    semantics)."""
    out = {c: np.asarray(v) for c, v in data.items()}
    for c in cols:
        x = out[c].astype(np.float64)
        m = x.mean() if len(x) else 0.0
        v = x.var() if len(x) else 0.0
        out[c] = (x - m) / np.sqrt(v + 1e-12)
    return out


def as_sets(data: dict, cols=None):
    """Row multiset as a sorted list of tuples (order-insensitive compare,
    NaN-tolerant)."""
    cols = list(cols) if cols is not None else sorted(data.keys())
    n = len(np.asarray(data[cols[0]]))
    rows = []
    for i in range(n):
        rows.append(tuple(round(float(np.nan_to_num(
            np.asarray(data[c])[i], nan=-1e9)), 4) for c in cols))
    return sorted(rows)
