"""Shared numpy join oracles for the conformance suites.

Imported both by in-process pytest modules (tests/ is on sys.path via the
conftest mechanism) and by the tests/dist/*.py subprocess workers (which
add this directory to sys.path explicitly).  numpy-only: subprocesses run
without pytest.
"""
import numpy as np


def np_join(left: dict, right: dict, how: str) -> dict:
    """Brute-force inner/left join on column 'k' of {'k','lv'} x
    {'k','rv'}; row order matches the engine's contract (left-row-major,
    matches in right original row order; unmatched left rows emit NaN
    right values)."""
    lk, rk = left["k"], right["k"]
    rows = []
    for i in range(len(lk)):
        matches = [j for j in range(len(rk)) if rk[j] == lk[i]]
        if matches:
            rows += [(i, j) for j in matches]
        elif how == "left":
            rows.append((i, None))
    out = {"k": [], "lv": [], "rv": []}
    for i, j in rows:
        out["k"].append(lk[i])
        out["lv"].append(left["lv"][i])
        out["rv"].append(right["rv"][j] if j is not None else np.nan)
    return {k: np.asarray(v) for k, v in out.items()}


def as_sets(data: dict, cols=None):
    """Row multiset as a sorted list of tuples (order-insensitive compare,
    NaN-tolerant)."""
    cols = list(cols) if cols is not None else sorted(data.keys())
    n = len(np.asarray(data[cols[0]]))
    rows = []
    for i in range(n):
        rows.append(tuple(round(float(np.nan_to_num(
            np.asarray(data[c])[i], nan=-1e9)), 4) for c in cols))
    return sorted(rows)
