"""XLA attention paths vs the fp32 reference (chunked flash, decode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ref import attention_ref
from repro.models import attention as A


def _qkv(B, Hq, Hkv, Sq, Skv, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, Hq, Sq, D), jnp.float32),
            jax.random.normal(ks[1], (B, Hkv, Skv, D), jnp.float32),
            jax.random.normal(ks[2], (B, Hkv, Skv, D), jnp.float32))


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 2, 2, 64, 32), (2, 4, 2, 96, 64), (1, 8, 1, 128, 32),
])
def test_full_attention_matches_ref(B, Hq, Hkv, S, D):
    q, k, v = _qkv(B, Hq, Hkv, S, S, D)
    got = A.full_attention(q, k, v, causal=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("qc,kc", [(16, 16), (32, 16), (16, 64),
                                   (64, 64), (40, 24)])
def test_chunked_attention_chunk_invariance(qc, kc):
    q, k, v = _qkv(1, 4, 2, 128, 128, 32, seed=qc * 100 + kc)
    got = A.chunked_attention(q, k, v, causal=True, q_chunk=qc, k_chunk=kc)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_chunked_attention_cross_no_causal():
    q, k, v = _qkv(2, 4, 4, 64, 96, 32, seed=9)
    got = A.chunked_attention(q, k, v, causal=False, q_chunk=32, k_chunk=32)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_chunked_right_aligned_causal():
    """Sq < Skv: query i attends to kv[:i + (Skv-Sq) + 1]."""
    q, k, v = _qkv(1, 2, 2, 32, 128, 32, seed=17)
    got = A.chunked_attention(q, k, v, causal=True, q_chunk=16, k_chunk=32)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_attention_dispatcher_selects_paths():
    q, k, v = _qkv(1, 2, 2, 64, 64, 32)
    small = A.attention(q, k, v, impl="xla", q_chunk=128, k_chunk=128)
    chunked = A.attention(q, k, v, impl="xla", q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(small), np.asarray(chunked),
                               rtol=2e-5, atol=2e-5)


def test_chunked_attention_grads_finite():
    q, k, v = _qkv(1, 2, 2, 64, 64, 32, seed=3)

    def loss(q, k, v):
        return jnp.sum(A.chunked_attention(q, k, v, causal=True,
                                           q_chunk=16, k_chunk=16) ** 2)

    g = jax.grad(loss)(q, k, v)
    assert np.isfinite(np.asarray(g)).all()
    # grads match the full-attention path's grads
    def loss_full(q, k, v):
        return jnp.sum(A.full_attention(q, k, v, causal=True) ** 2)
    gf = jax.grad(loss_full)(q, k, v)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gf),
                               rtol=5e-4, atol=5e-4)


def test_decode_attention_matches_masked_ref():
    B, Hq, Hkv, S, D = 2, 4, 2, 32, 16
    q, k, v = _qkv(B, Hq, Hkv, 1, S, D, seed=23)
    cache_len = 10          # positions 0..10 live (the just-written token)
    got = A.decode_attention(q, k, v, jnp.int32(cache_len))
    ref = attention_ref(q, k[:, :, :cache_len + 1], v[:, :, :cache_len + 1],
                        causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
