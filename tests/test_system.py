"""End-to-end system tests: the paper's single-source DE+DL program on a
single device (the 8-way version runs in tests/dist)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table import Table
from repro.data.unomt import (drug_feature_cols, feature_label_arrays,
                              gen_unomt_tables, rna_cols,
                              unomt_local_pipeline)
from repro.models import unomt_net
from repro.optim import adamw


def _features():
    raw = gen_unomt_tables(n_response=1024, n_drugs=64, n_cells=32, seed=7)
    tbls = {k: Table.from_dict(v) for k, v in raw.items()}
    feat = unomt_local_pipeline(tbls["response"], tbls["descriptors"],
                                tbls["fingerprints"], tbls["rna"],
                                out_capacity=2048)
    return feature_label_arrays(feat)


def test_unomt_pipeline_produces_learnable_features():
    X, y, mask = _features()
    n = int(np.asarray(mask).sum())
    assert n > 800                      # ~2% nulls dropped, rest joined
    assert X.shape[1] == 1 + 8 + 8      # conc + drug feats + rna feats
    Xv = np.asarray(X)[:n]
    assert np.isfinite(Xv).all()
    # every feature column carries signal (non-constant)
    assert (Xv.std(axis=0) > 1e-3).all()


def test_unomt_net_overfits_pipeline_output():
    """The full paper §4 story: features from the table engine train the
    drug-response network to a meaningfully lower loss."""
    X, y, mask = _features()
    cfg = unomt_net.UnomtNetConfig(n_features=X.shape[1], d_hidden=64,
                                   n_res_blocks=2, n_dense_tail=1,
                                   dropout=0.0)
    params = unomt_net.init(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=0, min_lr_ratio=1.0,
                                weight_decay=0.0)
    opt = adamw.init(params, opt_cfg)
    batch = {"x": X, "y": y, "mask": mask}

    @jax.jit
    def step(params, opt):
        (loss, m), g = jax.value_and_grad(
            unomt_net.mse_loss, has_aux=True)(params, cfg, batch)
        params, opt, _ = adamw.update(params, g, opt, opt_cfg)
        return params, opt, loss

    losses = []
    for _ in range(80):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < 0.2 * losses[0], (losses[0], losses[-1])


def test_table_to_tensor_handoff_is_jittable():
    """Stage 2 -> stage 3 -> stage 4 inside ONE jit (single-source claim)."""
    raw = gen_unomt_tables(n_response=256, n_drugs=16, n_cells=8, seed=1)
    tbls = {k: Table.from_dict(v) for k, v in raw.items()}
    cfg = unomt_net.UnomtNetConfig(n_features=17, d_hidden=32,
                                   n_res_blocks=1, n_dense_tail=1,
                                   dropout=0.0)
    params = unomt_net.init(jax.random.PRNGKey(1), cfg)

    @jax.jit
    def one_program(params, resp, desc, fp, rna):
        feat = unomt_local_pipeline(resp, desc, fp, rna,
                                    out_capacity=512)
        X, y, mask = feature_label_arrays(feat)
        loss, _ = unomt_net.mse_loss(params, cfg,
                                     {"x": X, "y": y, "mask": mask})
        return loss

    loss = one_program(params, tbls["response"], tbls["descriptors"],
                       tbls["fingerprints"], tbls["rna"])
    assert np.isfinite(float(loss))
