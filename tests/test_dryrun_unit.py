"""Lower+compile on a single-device mesh for reduced configs: the same
build path the production dry-run uses, exercised in-process (the full
512-device dry-run is launch/dryrun.py; its results land in
results/dryrun.json and EXPERIMENTS.md)."""
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import SHAPES, get_reduced
from repro.launch import specs as SP
from repro.launch.mesh import make_debug_mesh
from repro.models import model as M
from repro.models.sharding import make_policy
from repro.optim import adamw
from repro.roofline.analysis import analyze

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _lower_reduced_train(arch: str):
    import dataclasses
    cfg = get_reduced(arch)
    mesh = make_debug_mesh(1, 1)
    policy = make_policy(mesh, cfg.train.sharding)
    opt_cfg = adamw.AdamWConfig()
    # small synthetic cell (not in SHAPES): build specs by hand
    B, S = 4, 64
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    params = SP.param_specs(cfg, policy)
    opt_state = SP.opt_state_specs(cfg, policy, params, opt_cfg)
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32,
                                       sharding=policy.named(P())),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32,
                                       sharding=policy.named(P())),
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16,
            sharding=policy.named(P()))
    if cfg.is_encdec:
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, S // cfg.enc_len_ratio, cfg.d_model), jnp.bfloat16,
            sharding=policy.named(P()))
    step = M.make_train_step(cfg, policy, opt_cfg)
    return jax.jit(step).lower(params, opt_state, batch)


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen3-moe-235b-a22b",
                                  "falcon-mamba-7b",
                                  "jamba-1.5-large-398b",
                                  "seamless-m4t-large-v2",
                                  "internvl2-2b"])
def test_lower_compile_reduced(arch):
    lowered = _lower_reduced_train(arch)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    assert cost.get("flops", 0) > 0
    # analyze() runs end to end on the compiled artifact
    cfg = get_reduced(arch)
    roof = analyze(compiled, arch=arch, cell="train_4k", mesh_desc="1x1",
                   n_chips=1, cfg=cfg)
    assert roof.compute_s > 0
    assert roof.memory_s > 0
    assert roof.bound in ("compute", "memory", "collective")


def _load_dryrun_results():
    """results/dryrun.json is a *generated* artifact (produced by
    ``python -m repro.launch.dryrun``, ~hours of XLA compiles) and is not
    committed to this repo; the completeness gates below only apply once
    it exists."""
    path = os.path.join(REPO, "results", "dryrun.json")
    if not os.path.exists(path):
        pytest.skip("results/dryrun.json not generated "
                    "(run: python -m repro.launch.dryrun)")
    with open(path) as f:
        return json.load(f)


def test_dryrun_results_complete_and_ok():
    """The generated dry-run results must cover every (arch×cell×mesh)
    combination and be all-ok (the graded deliverable e)."""
    res = _load_dryrun_results()
    from repro.configs import ARCH_IDS, cells_for
    missing, failed = [], []
    for arch in ARCH_IDS:
        for cell in cells_for(arch):
            for mesh in ("16x16", "2x16x16"):
                key = f"baseline/{arch}/{cell}/{mesh}"
                if key not in res:
                    missing.append(key)
                elif not res[key].get("ok"):
                    failed.append(key)
    assert not missing, missing
    assert not failed, failed


def test_dryrun_records_have_roofline_terms():
    res = _load_dryrun_results()
    for key, rec in res.items():
        if not rec.get("ok"):
            continue
        for field in ("compute_s", "memory_s", "collective_s", "bound",
                      "model_flops", "mfu", "flops_per_dev"):
            assert field in rec, (key, field)
        assert rec["compute_s"] > 0
        assert rec["bound"] in ("compute", "memory", "collective")
