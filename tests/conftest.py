"""Shared fixtures/oracles for the repro test suite.

NOTE: no XLA_FLAGS tweaking here — in-process tests run on the single real
CPU device (per the assignment: only launch/dryrun.py builds the 512-device
placeholder mesh).  Multi-device distributed behaviour is exercised by
``tests/dist/dist_checks.py`` in a subprocess with
``--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import numpy as np
import pytest


# --------------------------------------------------------------------------
# numpy oracles for the table operators (a tiny "pandas" so the engine is
# checked against an independent implementation)
# --------------------------------------------------------------------------


def np_sort(data: dict, by, ascending=True) -> dict:
    """Stable multi-key sort of dict-of-1D-arrays."""
    keys = [np.asarray(data[k]) for k in reversed(list(by))]
    if not isinstance(ascending, bool):
        raise NotImplementedError
    order = np.lexsort(keys)
    if not ascending:
        order = order[::-1]
        # lexsort descending is not stable-reversed; re-sort stably:
        idx = np.arange(len(order))
        rev = [np.asarray(data[k]) for k in reversed(list(by))]
        rev = [-(r.astype(np.float64)) for r in rev]
        order = np.lexsort(rev + [idx][:0] or rev)
        order = np.lexsort(rev)
    return {k: np.asarray(v)[order] for k, v in data.items()}


def np_join_inner(left: dict, right: dict, on: str,
                  r_suffix: str = "_r") -> dict:
    """Inner join oracle: all (l,r) pairs with equal keys; order is
    left-row-major with right matches in right *sorted* order (matching the
    engine's sort-merge semantics up to within-key permutation)."""
    lk = np.asarray(left[on])
    rk = np.asarray(right[on])
    out_rows_l, out_rows_r = [], []
    for i in range(len(lk)):
        for j in range(len(rk)):
            if lk[i] == rk[j]:
                out_rows_l.append(i)
                out_rows_r.append(j)
    out = {}
    for k, v in left.items():
        out[k] = np.asarray(v)[out_rows_l]
    for k, v in right.items():
        if k == on:
            continue
        name = k + r_suffix if k in left else k
        out[name] = np.asarray(v)[out_rows_r]
    return out


def np_groupby_sum(data: dict, by: str, col: str) -> dict:
    keys = np.asarray(data[by])
    vals = np.asarray(data[col]).astype(np.float64)
    uk = np.unique(keys)
    return {by: uk,
            f"{col}_sum": np.array([vals[keys == k].sum() for k in uk])}


def as_sets(data: dict, cols=None):
    """Row multiset as a sorted list of tuples (order-insensitive compare)."""
    cols = list(cols) if cols is not None else sorted(data.keys())
    n = len(np.asarray(data[cols[0]]))
    rows = []
    for i in range(n):
        rows.append(tuple(round(float(np.asarray(data[c])[i]), 4)
                          for c in cols))
    return sorted(rows)


@pytest.fixture
def rng():
    return np.random.default_rng(42)
