"""Driver for the multi-device distributed checks.

Runs tests/dist/dist_checks.py in a subprocess with 8 forced host devices
so the main pytest process keeps the single real CPU device (the
assignment's rule: only the dry-run builds placeholder meshes).
"""
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)


def test_distributed_operator_checks():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist", "dist_checks.py")],
        env=env, capture_output=True, text=True, timeout=1800)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "distributed checks failed"
    assert "DIST CHECKS PASSED" in proc.stdout
