"""Local operator tests against numpy oracles (paper Table 2 operators)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import local_ops as L
from repro.core.table import INT_NULL, Table

from conftest import as_sets, np_join_inner


def mk(data, capacity=None):
    return Table.from_dict(data, capacity=capacity)


# --------------------------------------------------------------------------
# select / project / concat
# --------------------------------------------------------------------------


def test_select_masks_and_compacts():
    t = mk({"a": [1, 2, 3, 4, 5]}, capacity=8)
    out = L.select(t, t["a"] % 2 == 1)
    np.testing.assert_array_equal(out.to_numpy()["a"], [1, 3, 5])


def test_select_ignores_padding_rows():
    t = mk({"a": [1, 2]}, capacity=6)
    # mask true everywhere, including padding: padding must not leak in
    out = L.select(t, jnp.ones(6, bool))
    np.testing.assert_array_equal(out.to_numpy()["a"], [1, 2])


def test_project():
    t = mk({"a": [1], "b": [2], "c": [3]})
    out = L.project(t, ["c", "a"])
    assert out.names == ("c", "a")


def test_concat_with_padding():
    a = mk({"x": [1, 2]}, capacity=4)
    b = mk({"x": [3]}, capacity=3)
    out = L.concat(a, b)
    np.testing.assert_array_equal(out.to_numpy()["x"], [1, 2, 3])
    assert out.capacity == 7


def test_concat_schema_mismatch():
    with pytest.raises(ValueError):
        L.concat(mk({"x": [1]}), mk({"y": [1]}))


# --------------------------------------------------------------------------
# sort
# --------------------------------------------------------------------------


def test_sort_single_key(rng):
    vals = rng.integers(0, 50, 40)
    t = mk({"k": vals, "i": np.arange(40)}, capacity=64)
    out = L.sort_values(t, ["k"]).to_numpy()
    np.testing.assert_array_equal(out["k"], np.sort(vals))


def test_sort_is_stable(rng):
    keys = rng.integers(0, 4, 32)
    t = mk({"k": keys, "i": np.arange(32)})
    out = L.sort_values(t, ["k"]).to_numpy()
    for k in range(4):
        sub = out["i"][out["k"] == k]
        assert (np.diff(sub) > 0).all(), "within-key order must be stable"


def test_sort_multi_key_matches_lexsort(rng):
    a = rng.integers(0, 5, 30)
    b = rng.integers(0, 5, 30)
    t = mk({"a": a, "b": b}, capacity=40)
    out = L.sort_values(t, ["a", "b"]).to_numpy()
    order = np.lexsort((b, a))
    np.testing.assert_array_equal(out["a"], a[order])
    np.testing.assert_array_equal(out["b"], b[order])


def test_sort_descending(rng):
    vals = rng.integers(-100, 100, 25)
    t = mk({"k": vals})
    out = L.sort_values(t, ["k"], ascending=False).to_numpy()
    np.testing.assert_array_equal(out["k"], np.sort(vals)[::-1])


def test_sort_descending_float(rng):
    vals = rng.normal(size=25).astype(np.float32)
    t = mk({"k": vals})
    out = L.sort_values(t, ["k"], ascending=False).to_numpy()
    np.testing.assert_allclose(out["k"], np.sort(vals)[::-1])


def test_sort_keeps_padding_at_end():
    t = mk({"k": [3, 1, 2]}, capacity=6)
    out = L.sort_values(t, ["k"])
    assert int(out.nvalid) == 3
    np.testing.assert_array_equal(out.to_numpy()["k"], [1, 2, 3])


# --------------------------------------------------------------------------
# dedup / unique
# --------------------------------------------------------------------------


def test_drop_duplicates(rng):
    keys = rng.integers(0, 8, 50)
    t = mk({"k": keys, "v": np.arange(50)}, capacity=64)
    out = L.drop_duplicates(t, ["k"]).to_numpy()
    assert sorted(out["k"]) == sorted(np.unique(keys))
    # keeps the FIRST occurrence of each key
    for k, v in zip(out["k"], out["v"]):
        first = np.nonzero(keys == k)[0][0]
        assert v == first


def test_drop_duplicates_idempotent(rng):
    keys = rng.integers(0, 5, 30)
    t = mk({"k": keys})
    once = L.drop_duplicates(t, ["k"])
    twice = L.drop_duplicates(once, ["k"])
    assert as_sets(once.to_numpy()) == as_sets(twice.to_numpy())


def test_drop_duplicates_multi_col():
    t = mk({"a": [1, 1, 2, 1], "b": [1, 1, 2, 2]})
    out = L.drop_duplicates(t, ["a", "b"]).to_numpy()
    assert as_sets(out) == [(1.0, 1.0), (1.0, 2.0), (2.0, 2.0)]


# --------------------------------------------------------------------------
# groupby / aggregate
# --------------------------------------------------------------------------


def test_groupby_sum_mean_count(rng):
    keys = rng.integers(0, 6, 64)
    vals = rng.normal(size=64).astype(np.float32)
    t = mk({"k": keys, "v": vals}, capacity=80)
    out = L.groupby_aggregate(t, ["k"], {"v": ["sum", "mean", "count"]})
    o = out.to_numpy()
    for i, k in enumerate(o["k"]):
        sub = vals[keys == k]
        np.testing.assert_allclose(o["v_sum"][i], sub.sum(), rtol=1e-5)
        np.testing.assert_allclose(o["v_mean"][i], sub.mean(), rtol=1e-5)
        assert o["v_count"][i] == len(sub)
    assert o["v_count"].dtype == np.int32    # counts are int32, not float
    assert int(out.nvalid) == len(np.unique(keys))


def test_groupby_min_max(rng):
    keys = rng.integers(0, 4, 40)
    vals = rng.normal(size=40).astype(np.float32)
    t = mk({"k": keys, "v": vals})
    o = L.groupby_aggregate(t, ["k"], {"v": ["min", "max"]}).to_numpy()
    for i, k in enumerate(o["k"]):
        sub = vals[keys == k]
        np.testing.assert_allclose(o["v_min"][i], sub.min(), rtol=1e-6)
        np.testing.assert_allclose(o["v_max"][i], sub.max(), rtol=1e-6)


def test_groupby_multi_key():
    t = mk({"a": [1, 1, 2, 2, 1], "b": [1, 1, 1, 1, 2],
            "v": [1.0, 2.0, 3.0, 4.0, 5.0]})
    o = L.groupby_aggregate(t, ["a", "b"], {"v": "sum"}).to_numpy()
    got = {(int(a), int(b)): s for a, b, s in zip(o["a"], o["b"], o["v_sum"])}
    assert got == {(1, 1): 3.0, (2, 1): 7.0, (1, 2): 5.0}


def test_groupby_unknown_agg():
    t = mk({"k": [1], "v": [1.0]})
    with pytest.raises(ValueError):
        L.groupby_aggregate(t, ["k"], {"v": "median"})


def test_scalar_aggregate(rng):
    vals = rng.normal(size=33).astype(np.float32)
    t = mk({"v": vals}, capacity=64)
    assert np.isclose(float(L.aggregate(t, "v", "sum")), vals.sum(),
                      rtol=1e-5)
    assert np.isclose(float(L.aggregate(t, "v", "mean")), vals.mean(),
                      rtol=1e-5)
    assert np.isclose(float(L.aggregate(t, "v", "min")), vals.min())
    assert np.isclose(float(L.aggregate(t, "v", "max")), vals.max())
    count = L.aggregate(t, "v", "count")
    assert count.dtype == np.int32 and int(count) == 33
    assert np.isclose(float(L.aggregate(t, "v", "std")), vals.std(),
                      rtol=1e-4)


# --------------------------------------------------------------------------
# join
# --------------------------------------------------------------------------


def test_inner_join_matches_oracle(rng):
    left = {"k": rng.integers(0, 10, 30), "lv": np.arange(30)}
    right = {"k": rng.integers(0, 10, 20), "rv": np.arange(20) * 10}
    lt, rt = mk(left, capacity=40), mk(right, capacity=25)
    out = L.join(lt, rt, left_on=["k"], out_capacity=200).to_numpy()
    want = np_join_inner(left, right, "k")
    assert as_sets(out) == as_sets(want)


def test_left_join_unmatched_gets_null():
    lt = mk({"k": [1, 2, 3], "lv": [10, 20, 30]})
    rt = mk({"k": [2], "rv": [99]})
    out = L.join(lt, rt, left_on=["k"], how="left",
                 out_capacity=4).to_numpy()
    assert len(out["k"]) == 3
    rv = dict(zip(out["k"], out["rv"]))
    assert rv[2] == 99
    assert rv[1] == INT_NULL and rv[3] == INT_NULL


def test_join_multi_key():
    lt = mk({"a": [1, 1, 2], "b": [1, 2, 1], "lv": [10, 20, 30]})
    rt = mk({"a": [1, 2], "b": [2, 1], "rv": [5, 6]})
    out = L.join(lt, rt, left_on=["a", "b"], out_capacity=4).to_numpy()
    assert as_sets(out, ["a", "b", "lv", "rv"]) == [
        (1.0, 2.0, 20.0, 5.0), (2.0, 1.0, 30.0, 6.0)]


def test_join_different_key_names():
    lt = mk({"k": [1, 2], "lv": [10, 20]})
    rt = mk({"j": [2, 1], "rv": [5, 6]})
    out = L.join(lt, rt, left_on=["k"], right_on=["j"],
                 out_capacity=4).to_numpy()
    got = {(int(a), int(b)) for a, b in zip(out["k"], out["rv"])}
    assert got == {(1, 6), (2, 5)}


def test_join_overflow_counted():
    lt = mk({"k": [1, 1, 1]})
    rt = mk({"k": [1, 1, 1]})
    out, overflow = L.join(lt, rt, left_on=["k"], out_capacity=4,
                           return_overflow=True)
    assert int(out.nvalid) == 4
    assert int(overflow) == 5            # 9 matches, 4 kept


def test_join_name_collision_gets_suffix():
    lt = mk({"k": [1], "v": [10]})
    rt = mk({"k": [1], "v": [20]})
    out = L.join(lt, rt, left_on=["k"], out_capacity=2)
    assert "v" in out.names and "v_r" in out.names


def test_join_empty_right():
    lt = mk({"k": [1, 2]})
    rt = mk({"k": np.array([], np.int32)})
    out = L.join(lt, rt, left_on=["k"], out_capacity=4)
    assert int(out.nvalid) == 0


def test_cartesian_product():
    lt = mk({"a": [1, 2]})
    rt = mk({"b": [10, 20, 30]})
    out = L.cartesian_product(lt, rt, out_capacity=8).to_numpy()
    assert len(out["a"]) == 6
    assert as_sets(out) == sorted(
        [(float(a), float(b)) for a in [1, 2] for b in [10, 20, 30]])


# --------------------------------------------------------------------------
# membership / set ops
# --------------------------------------------------------------------------


def test_isin():
    t = mk({"k": [1, 2, 3, 4]}, capacity=6)
    vals = mk({"v": [2, 4, 9]})
    mask = np.asarray(L.isin(t, "k", vals, "v"))
    np.testing.assert_array_equal(mask[:4], [False, True, False, True])
    assert not mask[4:].any()


def test_intersect_and_difference(rng):
    a_keys = rng.integers(0, 12, 30)
    b_keys = rng.integers(0, 12, 30)
    a = mk({"k": a_keys}, capacity=40)
    b = mk({"k": b_keys}, capacity=40)
    inter = L.intersect(a, b, ["k"]).to_numpy()["k"]
    diff = L.difference(a, b, ["k"]).to_numpy()["k"]
    want_inter = np.intersect1d(a_keys, b_keys)
    np.testing.assert_array_equal(np.sort(inter), want_inter)
    want_diff = a_keys[~np.isin(a_keys, b_keys)]
    np.testing.assert_array_equal(np.sort(diff), np.sort(want_diff))


def test_union_dedups():
    a = mk({"k": [1, 2, 2]})
    b = mk({"k": [2, 3]})
    out = L.union(a, b).to_numpy()["k"]
    np.testing.assert_array_equal(np.sort(out), [1, 2, 3])


# --------------------------------------------------------------------------
# nulls / scaling
# --------------------------------------------------------------------------


def test_dropna_float_and_int():
    t = mk({"x": [1.0, np.nan, 3.0],
            "y": [1, 2, 3]})
    out = L.dropna(t, ["x"]).to_numpy()
    np.testing.assert_array_equal(out["y"], [1, 3])
    t2 = Table(columns={"y": jnp.array([1, INT_NULL, 3], jnp.int32)},
               nvalid=jnp.int32(3))
    out2 = L.dropna(t2, ["y"]).to_numpy()
    np.testing.assert_array_equal(out2["y"], [1, 3])


def test_fillna():
    t = mk({"x": [1.0, np.nan, 3.0]})
    out = L.fillna(t, {"x": -1.0}).to_numpy()
    np.testing.assert_allclose(out["x"], [1.0, -1.0, 3.0])


def test_isnull_masks_padding():
    t = mk({"x": [np.nan, 1.0]}, capacity=4)
    m = np.asarray(L.isnull(t, "x"))
    np.testing.assert_array_equal(m, [True, False, False, False])


def test_standard_scale(rng):
    vals = rng.normal(3.0, 2.5, 100).astype(np.float32)
    t = mk({"x": vals}, capacity=128)
    out = L.standard_scale(t, ["x"])
    live = out.to_numpy()["x"]
    assert abs(live.mean()) < 1e-4
    assert abs(live.std() - 1.0) < 1e-3


# --------------------------------------------------------------------------
# lex_searchsorted
# --------------------------------------------------------------------------


def test_lex_searchsorted_matches_numpy(rng):
    base = np.sort(rng.integers(0, 100, 50).astype(np.int32))
    q = rng.integers(-5, 105, 30).astype(np.int32)
    got_l = np.asarray(L.lex_searchsorted((jnp.asarray(base),),
                                          (jnp.asarray(q),), side="left"))
    got_r = np.asarray(L.lex_searchsorted((jnp.asarray(base),),
                                          (jnp.asarray(q),), side="right"))
    np.testing.assert_array_equal(got_l, np.searchsorted(base, q, "left"))
    np.testing.assert_array_equal(got_r, np.searchsorted(base, q, "right"))


def test_lex_searchsorted_two_keys():
    a = jnp.array([1, 1, 2, 2, 3], jnp.int32)
    b = jnp.array([1, 3, 1, 2, 0], jnp.int32)
    # query (2, 1): left insertion point is 2, right is 3
    lo = L.lex_searchsorted((a, b), (jnp.array([2]), jnp.array([1])),
                            side="left")
    hi = L.lex_searchsorted((a, b), (jnp.array([2]), jnp.array([1])),
                            side="right")
    assert int(lo[0]) == 2 and int(hi[0]) == 3
