"""Subprocess worker for tests/test_groupby_backends.py: distributed
groupby/unique/standard-scale conformance at a given world size.

Usage: XLA_FLAGS=...device_count=W python groupby_conformance.py W

For each key distribution, runs dist_groupby and dist_unique with BOTH
local backends under one shard_map and checks (a) the backends are
bit-identical (the shuffle is backend-independent, and per shard both
emit the canonical key-sorted table), (b) both match the pandas-semantics
numpy oracle as multisets, and (c) dist_standard_scale agrees across its
moment backends.  Value columns are integer-valued floats so sums are
exact in any addition order.  Prints ``GROUPBY CONFORMANCE PASSED`` on
success.
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
from oracles import (as_sets, np_drop_duplicates,  # noqa: E402
                     np_groupby_aggregate, np_standard_scale)

AGGS = {"v": ["sum", "count", "mean", "min", "max"]}


def distributions(rng, rows):
    return {
        "uniform": rng.integers(0, 12, rows).astype(np.int32),
        "skewed": np.where(rng.random(rows) < 0.6, 3,
                           rng.integers(0, 40, rows)).astype(np.int32),
        "allequal": np.full(rows, 7, np.int32),
    }


def main():
    world = int(sys.argv[1])
    import jax
    from jax.sharding import Mesh
    from repro.core import dist_ops as D
    from repro.core.context import make_context

    dev = np.array(jax.devices()[:world])
    ctx = make_context(Mesh(dev, ("data",)))
    rng = np.random.default_rng(world)
    rows = 96
    cap = (rows // world) * 4
    # every key's rows land on ONE shard and a shard holds <= `rows`
    # valid rows, so bucket_capacity=rows is distribution-proof
    sizes = {"num_buckets": 8, "bucket_capacity": rows}
    for name, keys in distributions(rng, rows).items():
        data = {"k": keys,
                "v": rng.integers(-100, 100, rows).astype(np.float32)}
        got = {}
        for impl in ("sort", "hash"):
            gt = D.distribute_table(ctx, data, capacity_per_shard=cap)
            pipe = D.DistributedPipeline(
                ctx, lambda c, a, impl=impl: D.dist_groupby(
                    c, a, ["k"], AGGS, overcommit=4.0, local_impl=impl,
                    groupby_sizes=(sizes if impl == "hash" else None)))
            out, dropped = pipe(gt)
            assert int(np.max(np.asarray(dropped))) == 0, (name, impl)
            got[impl] = D.collect_table(ctx, out)
        for c in got["sort"]:
            np.testing.assert_array_equal(got["sort"][c], got["hash"][c],
                                          err_msg=f"{name}/{c}")
        want = np_groupby_aggregate(data, ["k"], AGGS)
        assert as_sets(got["hash"]) == as_sets(
            {c: v.astype(np.float64) for c, v in want.items()}), name
        print(f"groupby {name}: ok ({len(want['k'])} groups)", flush=True)

        got = {}
        for impl in ("sort", "hash"):
            gt = D.distribute_table(ctx, data, capacity_per_shard=cap)
            pipe = D.DistributedPipeline(
                ctx, lambda c, a, impl=impl: D.dist_unique(
                    c, a, ["k"], overcommit=4.0, local_impl=impl,
                    groupby_sizes=(sizes if impl == "hash" else None)))
            out, dropped = pipe(gt)
            assert int(np.max(np.asarray(dropped))) == 0, (name, impl)
            got[impl] = D.collect_table(ctx, out)
        for c in got["sort"]:
            np.testing.assert_array_equal(got["sort"][c], got["hash"][c],
                                          err_msg=f"unique {name}/{c}")
        assert sorted(got["hash"]["k"]) == sorted(
            np_drop_duplicates(data, ["k"])["k"]), name
        print(f"unique {name}: ok", flush=True)

    data = {"k": rng.integers(0, 9, rows).astype(np.int32),
            "x": rng.normal(size=rows).astype(np.float32)}
    want = np_standard_scale(data, ["x"])
    for impl in (None, "sort", "hash"):
        gt = D.distribute_table(ctx, data, capacity_per_shard=cap)
        pipe = D.DistributedPipeline(
            ctx, lambda c, a, impl=impl: D.dist_standard_scale(
                c, a, ["x"], local_impl=impl))
        out = pipe(gt)
        got = D.collect_table(ctx, out)
        np.testing.assert_allclose(got["x"], want["x"], rtol=1e-4,
                                   atol=1e-4, err_msg=str(impl))
    print("standard_scale: ok", flush=True)
    print("GROUPBY CONFORMANCE PASSED")


if __name__ == "__main__":
    sys.exit(main())
