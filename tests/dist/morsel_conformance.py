"""Subprocess worker for tests/test_morsel.py: chunked (morsel-driven)
execution conformance at a given world size.

Usage: XLA_FLAGS=...device_count=W python morsel_conformance.py W

Checks the out-of-core chunk loops against the monolithic distributed
operators on data that *fits*, where results must agree exactly:

* join (build-resident and build-restreamed): same content — row order is
  permuted by chunk boundaries exactly as shard boundaries already
  permute it, so both sides are canonicalized by a full lexsort before
  the exact compare; also checked against the numpy oracle;
* groupby: bit-identical arrays (same shard assignment per key, canonical
  per-shard layout, exact partial sums on integer-valued floats);
* sort: bit-identical arrays including tie order (both paths tie in
  original row order);
* zero-row inputs stream one empty terminal morsel through every op.

All legs assert the aggregated across-chunk dropped counter is zero.
Prints ``MORSEL CONFORMANCE PASSED`` on success.
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
from oracles import as_sets, np_groupby_aggregate, np_join  # noqa: E402


def canon(d: dict) -> dict:
    order = np.lexsort(tuple(np.nan_to_num(d[k], nan=-1e9)
                             for k in sorted(d)))
    return {k: v[order] for k, v in d.items()}


def assert_same(a: dict, b: dict, msg=""):
    assert set(a) == set(b), msg
    for k in a:
        np.testing.assert_array_equal(
            np.nan_to_num(a[k], nan=-1e9), np.nan_to_num(b[k], nan=-1e9),
            err_msg=f"{msg} col={k}")


def main():
    world = int(sys.argv[1])
    import jax
    from jax.sharding import Mesh
    from repro.core import dist_ops as D
    from repro.core import morsel as M
    from repro.core.context import make_context

    dev = np.array(jax.devices()[:world])
    ctx = make_context(Mesh(dev, ("data",)))
    rng = np.random.default_rng(world)

    rows, nkeys, chunk = 2000, 150, 300
    left = {"k": rng.integers(0, nkeys, rows).astype(np.int64),
            "lv": rng.integers(-50, 50, rows).astype(np.float64)}
    right = {"k": np.arange(nkeys, dtype=np.int64),
             "rv": rng.integers(0, 100, nkeys).astype(np.float64)}
    out_cap = 8192

    # ---- join: chunked (resident + restream) vs monolithic vs oracle
    gl = D.distribute_table(ctx, left)
    gr = D.distribute_table(ctx, right)
    pipe = D.DistributedPipeline(ctx, lambda c, a, b: D.dist_join(
        c, a, b, left_on=["k"], out_capacity=out_cap))
    mono, md = pipe(gl, gr)
    assert int(np.max(np.asarray(md))) == 0
    mono = canon(D.collect_table(ctx, mono))
    for build, rchunk in (("resident", nkeys), ("restream", 64)):
        out, dropped = M.chunked_dist_join(
            ctx, M.ChunkedTable(left, chunk),
            M.ChunkedTable(right, rchunk), left_on=["k"], build=build,
            out_capacity_per_shard=out_cap)
        assert dropped == 0, build
        assert_same(canon(out), mono, f"join/{build}")
        print(f"join/{build}: ok ({len(out['k'])} rows)", flush=True)
    lk32 = {"k": left["k"].astype(np.int32),
            "lv": left["lv"].astype(np.float32)}
    rk32 = {"k": right["k"].astype(np.int32),
            "rv": right["rv"].astype(np.float32)}
    assert as_sets(mono) == as_sets(np_join(lk32, rk32, "inner"))

    # ---- left join through the resident build path (odd keys unmatched)
    rsub = {k: v[::2] for k, v in right.items()}
    rsub32 = {k: v[::2] for k, v in rk32.items()}
    outl, dl = M.chunked_dist_join(
        ctx, M.ChunkedTable(left, chunk), rsub, left_on=["k"],
        how="left", out_capacity_per_shard=out_cap)
    assert dl == 0
    assert np.isnan(outl["rv"]).any()   # unmatched rows really occur
    assert as_sets(canon(outl)) == as_sets(np_join(lk32, rsub32, "left"))
    print("join/left: ok", flush=True)

    # ---- groupby: chunked partial-merge vs monolithic, bit-identical
    # (explicit slab sizes: the traced hash-backend heuristic undersizes
    # hot buckets at this duplication level, same idiom as
    # groupby_conformance.py)
    gsizes = {"num_buckets": 8, "bucket_capacity": rows}
    aggs = {"lv": ["sum", "mean", "count", "min", "max"]}
    gp = D.DistributedPipeline(ctx, lambda c, t: D.dist_groupby(
        c, t, ["k"], aggs, groupby_sizes=gsizes))
    monog, gd = gp(gl)
    assert int(np.max(np.asarray(gd))) == 0
    monog = D.collect_table(ctx, monog)
    cg, cgd = M.chunked_dist_groupby(ctx, M.ChunkedTable(left, chunk),
                                     ["k"], aggs,
                                     group_capacity_per_shard=nkeys,
                                     groupby_sizes=gsizes)
    assert cgd == 0
    assert_same(cg, monog, "groupby")
    want = np_groupby_aggregate(lk32, ["k"], aggs)
    got = canon(cg)
    wantc = canon({k: np.asarray(v) for k, v in want.items()})
    for k in wantc:
        np.testing.assert_allclose(got[k].astype(np.float64), wantc[k],
                                   rtol=1e-6, err_msg=f"groupby oracle {k}")
    print(f"groupby: ok ({len(cg['k'])} groups, bit-identical)",
          flush=True)

    # ---- sort: chunked runs + k-way merge vs monolithic, bit-identical
    for ascending in (True, False):
        sp = D.DistributedPipeline(ctx, lambda c, t, a=ascending:
                                   D.dist_sort(c, t, ["k"], ascending=a))
        monos, sd = sp(gl)
        assert int(np.max(np.asarray(sd))) == 0
        monos = D.collect_table(ctx, monos)
        cs, csd = M.chunked_dist_sort(ctx, M.ChunkedTable(left, chunk),
                                      ["k"], ascending=ascending)
        assert csd == 0
        assert_same(cs, monos, f"sort asc={ascending}")
        print(f"sort asc={ascending}: ok (ties bit-identical)", flush=True)

    # ---- zero-row sources: one empty terminal morsel per op
    empty = {"k": np.zeros(0, np.int64), "lv": np.zeros(0, np.float64)}
    eo, ed = M.chunked_dist_join(ctx, empty, right, left_on=["k"])
    assert ed == 0 and len(eo["k"]) == 0
    eo, ed = M.chunked_dist_join(ctx, empty, M.ChunkedTable(right, 64),
                                 left_on=["k"], build="restream")
    assert ed == 0 and len(eo["k"]) == 0
    eg, ed = M.chunked_dist_groupby(ctx, empty, ["k"], {"lv": "mean"})
    assert ed == 0 and len(eg["k"]) == 0
    es, ed = M.chunked_dist_sort(ctx, empty, ["k"])
    assert ed == 0 and len(es["k"]) == 0
    print("empty sources: ok", flush=True)

    print("MORSEL CONFORMANCE PASSED")


if __name__ == "__main__":
    sys.exit(main())
