"""Subprocess worker for tests/test_setop_backends.py: distributed
isin/intersect/difference conformance at a given world size.

Usage: XLA_FLAGS=...device_count=W python setop_conformance.py W

For each key distribution, runs dist_isin, dist_intersect and
dist_difference with BOTH local semi-join backends under one shard_map
and checks (a) the backends are bit-identical per shard (the shuffle is
backend-independent, and equal keys co-locate because the partition hash
is over key *values*), and (b) both match the pandas-semantics numpy
oracle as row multisets (shard order is world-size-dependent, global
content is not).  Prints ``SETOP CONFORMANCE PASSED`` on success.
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
from oracles import (as_sets, np_difference, np_intersect,  # noqa: E402
                     np_isin)


def distributions(rng, rows):
    return {
        "uniform": (rng.integers(0, 12, rows).astype(np.int32),
                    rng.integers(6, 18, rows // 2).astype(np.int32)),
        "skewed": (np.where(rng.random(rows) < 0.6, 3,
                            rng.integers(0, 40, rows)).astype(np.int32),
                   np.where(rng.random(rows // 2) < 0.5, 3,
                            rng.integers(20, 60,
                                         rows // 2)).astype(np.int32)),
        "allequal": (np.full(rows, 7, np.int32),
                     np.full(rows // 2, 7, np.int32)),
    }


def main():
    world = int(sys.argv[1])
    import jax
    from jax.sharding import Mesh
    from repro.core import dist_ops as D
    from repro.core.context import make_context

    dev = np.array(jax.devices()[:world])
    ctx = make_context(Mesh(dev, ("data",)))
    rng = np.random.default_rng(world)
    rows = 96
    cap = (rows // world) * 4
    # post-shuffle a shard holds <= rows valid rows, so slab capacity
    # = rows is distribution-proof (allequal puts every row in 1 bucket)
    sizes = {"num_buckets": 8, "bucket_capacity": rows,
             "probe_capacity": rows}
    for name, (ka, kb) in distributions(rng, rows).items():
        a = {"k": ka,
             "v": rng.integers(-100, 100, rows).astype(np.float32)}
        b = {"k": kb,
             "v": rng.integers(-100, 100, rows // 2).astype(np.float32)}

        got = {}
        for impl in ("sortmerge", "hash"):
            ga = D.distribute_table(ctx, a, capacity_per_shard=cap)
            gv = D.distribute_table(ctx, b, capacity_per_shard=cap)
            pipe = D.DistributedPipeline(
                ctx, lambda c, x, y, impl=impl: D.dist_isin(
                    c, x, "k", y, "k", overcommit=4.0, local_impl=impl,
                    semi_sizes=(sizes if impl == "hash" else None)))
            out, dropped = pipe(ga, gv)
            assert int(np.max(np.asarray(dropped))) == 0, (name, impl)
            got[impl] = D.collect_table(ctx, out)
        for c in got["sortmerge"]:
            np.testing.assert_array_equal(
                got["sortmerge"][c], got["hash"][c],
                err_msg=f"isin {name}/{c}")
        mask = np_isin(a, "k", b, "k")
        want = {c: np.asarray(v)[mask] for c, v in a.items()}
        assert as_sets(got["hash"]) == as_sets(want), f"isin {name}"
        print(f"isin {name}: ok ({int(mask.sum())} rows kept)",
              flush=True)

        for op, dist_fn, oracle in (
                ("intersect", D.dist_intersect, np_intersect),
                ("difference", D.dist_difference, np_difference)):
            got = {}
            for impl in ("sortmerge", "hash"):
                ga = D.distribute_table(ctx, a, capacity_per_shard=cap)
                gb = D.distribute_table(ctx, b, capacity_per_shard=cap)
                pipe = D.DistributedPipeline(
                    ctx, lambda c, x, y, impl=impl, fn=dist_fn: fn(
                        c, x, y, ["k"], overcommit=4.0, local_impl=impl,
                        semi_sizes=(sizes if impl == "hash" else None)))
                out, dropped = pipe(ga, gb)
                assert int(np.max(np.asarray(dropped))) == 0, (name, impl)
                got[impl] = D.collect_table(ctx, out)
            for c in got["sortmerge"]:
                np.testing.assert_array_equal(
                    got["sortmerge"][c], got["hash"][c],
                    err_msg=f"{op} {name}/{c}")
            assert as_sets(got["hash"]) == as_sets(oracle(a, b, ["k"])), \
                f"{op} {name}"
            print(f"{op} {name}: ok ({len(got['hash']['k'])} rows)",
                  flush=True)
    print("SETOP CONFORMANCE PASSED")


if __name__ == "__main__":
    sys.exit(main())
