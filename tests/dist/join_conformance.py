"""Subprocess worker for tests/test_join_backends.py: distributed join
conformance at a given world size.

Usage: XLA_FLAGS=...device_count=W python join_conformance.py W

For each key distribution x join type, runs dist_join with BOTH local
backends under one shard_map and checks (a) the backends are
bit-identical, (b) both match a brute-force numpy oracle as multisets.
Prints ``JOIN CONFORMANCE PASSED`` on success.
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
from oracles import as_sets, np_join  # noqa: E402


def distributions(rng, rows):
    uniq = np.arange(rows, dtype=np.int32)
    rng.shuffle(uniq)
    return {
        "unique": (uniq, rng.permutation(uniq)),
        "dup10": (rng.integers(0, max(rows // 10, 1), rows)
                  .astype(np.int32),
                  rng.integers(0, max(rows // 10, 1), rows)
                  .astype(np.int32)),
        "alldup": (np.full(rows, 7, np.int32), np.full(rows, 7, np.int32)),
    }


def main():
    world = int(sys.argv[1])
    import jax
    from jax.sharding import Mesh
    from repro.core import dist_ops as D
    from repro.core.context import make_context

    dev = np.array(jax.devices()[:world])
    ctx = make_context(Mesh(dev, ("data",)))
    rng = np.random.default_rng(world)
    rows = 96
    for name, (lk, rk) in distributions(rng, rows).items():
        left = {"k": lk, "lv": rng.normal(size=rows).astype(np.float32)}
        right = {"k": rk, "rv": rng.normal(size=rows).astype(np.float32)}
        cap = (rows // world) * 4
        out_cap = rows * rows + rows       # alldup worst case
        sizes = {"num_buckets": 8, "bucket_capacity": rows,
                 "probe_capacity": rows}
        for how in ("inner", "left"):
            got = {}
            for impl in ("sortmerge", "hash"):
                gl = D.distribute_table(ctx, left, capacity_per_shard=cap)
                gr = D.distribute_table(ctx, right, capacity_per_shard=cap)
                pipe = D.DistributedPipeline(
                    ctx, lambda c, a, b, impl=impl, how=how: D.dist_join(
                        c, a, b, left_on=["k"], how=how,
                        out_capacity=out_cap, overcommit=4.0,
                        local_impl=impl,
                        local_join_sizes=(sizes if impl == "hash"
                                          else None)))
                out, dropped = pipe(gl, gr)
                assert int(np.max(np.asarray(dropped))) == 0, \
                    (name, how, impl)
                got[impl] = D.collect_table(ctx, out)
            for k in got["sortmerge"]:
                np.testing.assert_array_equal(
                    np.nan_to_num(got["sortmerge"][k], nan=-1e9),
                    np.nan_to_num(got["hash"][k], nan=-1e9),
                    err_msg=f"{name}/{how}/{k}")
            want = np_join(left, right, how)
            assert as_sets(got["hash"]) == as_sets(want), (name, how)
            print(f"{name}/{how}: ok ({len(want['k'])} rows)", flush=True)
    print("JOIN CONFORMANCE PASSED")


if __name__ == "__main__":
    sys.exit(main())
