"""Subprocess worker for tests/test_serving.py: feature-fetch conformance
at a given world size.

Usage: XLA_FLAGS=...device_count=W python serving_conformance.py W

Checks the serving engine's FeatureStore — morsel-ingested resident
feature table + cached shuffle/join lookup pipeline — against numpy
gathers on data that fits:

* lookup of mixed present/missing keys: features align with the probe
  order, the found mask flags exactly the present keys, zero drops;
* skewed probe (every key the same hot key, probe at full capacity):
  all found, zero drops — the skew-proof slab sizing;
* contains() membership mask equals numpy isin;
* duplicate probe keys each resolve (lookup is a join, not a dedup).

Prints ``SERVING CONFORMANCE PASSED`` on success.
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def main():
    world = int(sys.argv[1])
    import jax
    from jax.sharding import Mesh
    from repro.core.context import make_context
    from repro.core.morsel import ChunkedTable
    from repro.serving import FeatureStore

    devs = np.array(jax.devices())
    assert devs.size == world, f"wanted {world} devices, got {devs.size}"
    ctx = make_context(Mesh(devs, ("rows",)))

    rng = np.random.default_rng(7)
    n = 200
    keys = rng.permutation(n).astype(np.int32)      # unique, shuffled
    table = {
        "k": keys,
        "f0": rng.normal(size=n).astype(np.float32),
        "f1": rng.normal(size=n).astype(np.float32),
        "f2": rng.integers(0, 100, n).astype(np.int32),
    }
    store = FeatureStore(ctx, "k", ChunkedTable(table, chunk_rows=32),
                        probe_capacity=64)
    assert store.dropped == 0, f"ingest dropped {store.dropped}"

    by_key = {c: table[c][np.argsort(keys)] for c in ("f0", "f1", "f2")}

    # mixed present / missing probe
    probe = rng.integers(-20, n + 20, 50).astype(np.int32)
    feats, found = store.lookup(probe)
    np.testing.assert_array_equal(found, (probe >= 0) & (probe < n))
    for c in ("f0", "f1", "f2"):
        expect = np.where(found, by_key[c][np.clip(probe, 0, n - 1)], 0)
        np.testing.assert_array_equal(feats[c], expect, err_msg=c)
    assert store.dropped == 0

    # skewed probe: the whole capacity hits one hot key
    hot = np.full(store.probe_capacity, int(keys[0]), np.int32)
    feats, found = store.lookup(hot)
    assert found.all(), "hot-key probe lost rows"
    np.testing.assert_array_equal(
        feats["f0"], np.full(len(hot), by_key["f0"][keys[0]]))
    assert store.dropped == 0, f"hot-key probe dropped {store.dropped}"

    # duplicate keys each resolve independently
    dup = np.array([5, 5, 7, 5], np.int32)
    feats, found = store.lookup(dup)
    assert found.all()
    np.testing.assert_array_equal(feats["f2"], by_key["f2"][dup])

    # membership path
    np.testing.assert_array_equal(store.contains(probe),
                                  (probe >= 0) & (probe < n))
    assert store.dropped == 0

    print("SERVING CONFORMANCE PASSED")


if __name__ == "__main__":
    main()
