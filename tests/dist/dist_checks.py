"""Multi-device distributed operator checks (run by tests/test_dist.py).

Runs in a subprocess with ``--xla_force_host_platform_device_count=8`` so
the main pytest process keeps the single real CPU device.  Every check
builds a global row-sharded table, runs a distributed operator through
:class:`DistributedPipeline` (one shard_map program), collects the result
back to numpy and compares it with an independent numpy oracle.

Prints ``DIST CHECKS PASSED`` on success (the driver asserts on it).
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core import dist_ops as D  # noqa: E402
from repro.core.context import make_context  # noqa: E402

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
from oracles import as_sets  # noqa: E402

WORLD = 8


def make_ctx():
    dev = np.array(jax.devices()[:WORLD])
    return make_context(Mesh(dev, ("data",)))


def check_roundtrip(ctx, rng):
    data = {"a": rng.integers(0, 100, 41).astype(np.int32),
            "b": rng.normal(size=41).astype(np.float32)}
    t = D.distribute_table(ctx, data, capacity_per_shard=8)
    back = D.collect_table(ctx, t)
    for k in data:
        np.testing.assert_array_equal(back[k], data[k])
    print("roundtrip ok")


def check_join(ctx, rng, local_impl):
    rows, nkeys = 160, 16
    left = {"k": rng.integers(0, nkeys, rows).astype(np.int32),
            "lv": rng.normal(size=rows).astype(np.float32)}
    right = {"k": rng.integers(0, nkeys, rows).astype(np.int32),
             "rv": rng.normal(size=rows).astype(np.float32)}
    cap = (rows // WORLD) * 3
    gl = D.distribute_table(ctx, left, capacity_per_shard=cap)
    gr = D.distribute_table(ctx, right, capacity_per_shard=cap)
    sizes = {"num_buckets": 16, "bucket_capacity": rows,
             "probe_capacity": rows}
    pipe = D.DistributedPipeline(
        ctx, lambda c, a, b: D.dist_join(
            c, a, b, left_on=["k"], out_capacity=rows * rows // nkeys * 4,
            overcommit=4.0, local_impl=local_impl,
            local_join_sizes=sizes if local_impl == "hash" else None))
    out, dropped = pipe(gl, gr)
    assert int(np.max(np.asarray(dropped))) == 0
    got = D.collect_table(ctx, out)
    # numpy oracle: every (l, r) pair with equal keys
    lk, rk = left["k"], right["k"]
    pairs = [(i, j) for i in range(rows) for j in range(rows)
             if lk[i] == rk[j]]
    want = {"k": lk[[i for i, _ in pairs]],
            "lv": left["lv"][[i for i, _ in pairs]],
            "rv": right["rv"][[j for _, j in pairs]]}
    assert as_sets(got) == as_sets(want), f"join[{local_impl}] mismatch"
    print(f"dist_join[{local_impl}] ok ({len(pairs)} rows)")


def check_join_backends_agree(ctx, rng):
    rows, nkeys = 120, 12
    left = {"k": rng.integers(0, nkeys, rows).astype(np.int32),
            "lv": rng.normal(size=rows).astype(np.float32)}
    right = {"k": rng.integers(0, nkeys, rows).astype(np.int32),
             "rv": rng.normal(size=rows).astype(np.float32)}
    cap = (rows // WORLD) * 3
    outs = {}
    for impl in ("sortmerge", "hash"):
        gl = D.distribute_table(ctx, left, capacity_per_shard=cap)
        gr = D.distribute_table(ctx, right, capacity_per_shard=cap)
        sizes = {"num_buckets": 8, "bucket_capacity": rows,
                 "probe_capacity": rows}
        pipe = D.DistributedPipeline(
            ctx, lambda c, a, b, impl=impl: D.dist_join(
                c, a, b, left_on=["k"], out_capacity=2048, overcommit=4.0,
                local_impl=impl,
                local_join_sizes=sizes if impl == "hash" else None))
        out, dropped = pipe(gl, gr)
        assert int(np.max(np.asarray(dropped))) == 0
        outs[impl] = D.collect_table(ctx, out)
    a, b = outs["sortmerge"], outs["hash"]
    assert set(a) == set(b)
    for k in a:  # per-shard local order is identical, so full equality
        np.testing.assert_array_equal(a[k], b[k])
    print("dist_join backends bit-identical ok")


def check_join_planned(ctx, rng):
    """plan_dist_join_sizes: exact host-side capacities — zero drops and
    bit-identical output to the generously-overcommitted baseline run,
    under both local backends."""
    rows, nkeys = 120, 12
    left = {"k": rng.integers(0, nkeys, rows).astype(np.int32),
            "lv": rng.normal(size=rows).astype(np.float32)}
    right = {"k": rng.integers(0, nkeys, rows).astype(np.int32),
             "rv": rng.normal(size=rows).astype(np.float32)}
    cap = (rows // WORLD) * 3
    outs = {}
    for impl in ("sortmerge", "hash"):
        plan = D.plan_dist_join_sizes([left["k"]], [right["k"]],
                                      world=WORLD, local_impl=impl)
        gl = D.distribute_table(ctx, left, capacity_per_shard=cap)
        gr = D.distribute_table(ctx, right, capacity_per_shard=cap)
        pipe = D.DistributedPipeline(
            ctx, lambda c, a, b, impl=impl, plan=plan: D.dist_join(
                c, a, b, left_on=["k"],
                out_capacity=plan["out_capacity"],
                shuffle_sizes=plan["shuffle_sizes"], local_impl=impl,
                local_join_sizes=plan["local_join_sizes"]))
        out, dropped = pipe(gl, gr)
        assert int(np.max(np.asarray(dropped))) == 0, impl
        outs[impl] = D.collect_table(ctx, out)
    lk, rk = left["k"], right["k"]
    pairs = [(i, j) for i in range(rows) for j in range(rows)
             if lk[i] == rk[j]]
    want = {"k": lk[[i for i, _ in pairs]],
            "lv": left["lv"][[i for i, _ in pairs]],
            "rv": right["rv"][[j for _, j in pairs]]}
    for impl, got in outs.items():
        assert as_sets(got) == as_sets(want), f"planned[{impl}] mismatch"
    print("dist_join planned sizes ok")


def check_groupby(ctx, rng):
    data = {"k": rng.integers(0, 9, 100).astype(np.int32),
            "v": rng.normal(size=100).astype(np.float32)}
    t = D.distribute_table(ctx, data, capacity_per_shard=40)
    pipe = D.DistributedPipeline(
        ctx, lambda c, a: D.dist_groupby(c, a, ["k"], {"v": "sum"},
                                         overcommit=4.0))
    out, dropped = pipe(t)
    assert int(np.max(np.asarray(dropped))) == 0
    got = D.collect_table(ctx, out)
    uk = np.unique(data["k"])
    want = {k: float(data["v"][data["k"] == k].sum()) for k in uk}
    assert len(got["k"]) == len(uk)
    for k, s in zip(got["k"], got["v_sum"]):
        np.testing.assert_allclose(s, want[int(k)], rtol=1e-4, atol=1e-4)
    print("dist_groupby ok")


def check_unique(ctx, rng):
    data = {"k": rng.integers(0, 20, 120).astype(np.int32)}
    t = D.distribute_table(ctx, data, capacity_per_shard=40)
    pipe = D.DistributedPipeline(
        ctx, lambda c, a: D.dist_unique(c, a, ["k"], overcommit=4.0))
    out, dropped = pipe(t)
    assert int(np.max(np.asarray(dropped))) == 0
    got = D.collect_table(ctx, out)
    assert sorted(got["k"]) == sorted(np.unique(data["k"]))
    print("dist_unique ok")


def check_sort(ctx, rng, local_impl):
    data = {"k": rng.integers(0, 1000, 90).astype(np.int32),
            "v": rng.normal(size=90).astype(np.float32)}
    t = D.distribute_table(ctx, data, capacity_per_shard=40)
    pipe = D.DistributedPipeline(
        ctx, lambda c, a: D.dist_sort(c, a, ["k"], overcommit=4.0,
                                      local_impl=local_impl))
    out, dropped = pipe(t)
    assert int(np.max(np.asarray(dropped))) == 0
    got = D.collect_table(ctx, out)
    np.testing.assert_array_equal(got["k"], np.sort(data["k"]))
    assert as_sets(got) == as_sets(data)
    print(f"dist_sort[{local_impl}] ok")


def check_isin(ctx, rng, local_impl):
    rows = 96
    data = {"k": rng.integers(0, 30, rows).astype(np.int32),
            "v": rng.normal(size=rows).astype(np.float32)}
    vals = {"m": rng.integers(15, 45, rows // 2).astype(np.int32)}
    cap = (rows // WORLD) * 4
    t = D.distribute_table(ctx, data, capacity_per_shard=cap)
    v = D.distribute_table(ctx, vals, capacity_per_shard=cap)
    sizes = {"num_buckets": 8, "bucket_capacity": rows,
             "probe_capacity": rows}
    pipe = D.DistributedPipeline(
        ctx, lambda c, a, b: D.dist_isin(
            c, a, "k", b, "m", overcommit=4.0, local_impl=local_impl,
            semi_sizes=sizes if local_impl == "hash" else None))
    out, dropped = pipe(t, v)
    assert int(np.max(np.asarray(dropped))) == 0
    got = D.collect_table(ctx, out)
    keep = np.isin(data["k"], vals["m"])
    want = {c: a[keep] for c, a in data.items()}
    assert as_sets(got) == as_sets(want), f"isin[{local_impl}] mismatch"
    print(f"dist_isin[{local_impl}] ok ({int(keep.sum())} rows)")


def check_setops(ctx, rng, local_impl):
    rows = 80
    a = {"k": rng.integers(0, 25, rows).astype(np.int32)}
    b = {"k": rng.integers(12, 40, rows).astype(np.int32)}
    cap = (rows // WORLD) * 4
    sizes = {"num_buckets": 8, "bucket_capacity": rows,
             "probe_capacity": rows}
    semi = sizes if local_impl == "hash" else None
    ga = D.distribute_table(ctx, a, capacity_per_shard=cap)
    gb = D.distribute_table(ctx, b, capacity_per_shard=cap)
    pipe = D.DistributedPipeline(
        ctx, lambda c, x, y: D.dist_intersect(
            c, x, y, ["k"], overcommit=4.0, local_impl=local_impl,
            semi_sizes=semi))
    out, dropped = pipe(ga, gb)
    assert int(np.max(np.asarray(dropped))) == 0
    got = D.collect_table(ctx, out)
    want = np.intersect1d(a["k"], b["k"])
    assert sorted(got["k"]) == sorted(want), local_impl
    ga = D.distribute_table(ctx, a, capacity_per_shard=cap)
    gb = D.distribute_table(ctx, b, capacity_per_shard=cap)
    pipe = D.DistributedPipeline(
        ctx, lambda c, x, y: D.dist_difference(
            c, x, y, ["k"], overcommit=4.0, local_impl=local_impl,
            semi_sizes=semi))
    out, dropped = pipe(ga, gb)
    assert int(np.max(np.asarray(dropped))) == 0
    got = D.collect_table(ctx, out)
    keep = ~np.isin(a["k"], b["k"])
    assert sorted(got["k"]) == sorted(a["k"][keep]), local_impl
    print(f"dist_intersect/difference[{local_impl}] ok")


def check_morsel(ctx, rng):
    """Out-of-core chunk loops at world 8: chunked == monolithic."""
    from repro.core import morsel as M
    rows, nkeys, chunk = 960, 64, 160
    data = {"k": rng.integers(0, nkeys, rows).astype(np.int32),
            "v": rng.integers(-50, 50, rows).astype(np.float32)}
    right = {"k": np.arange(nkeys, dtype=np.int32),
             "w": rng.integers(0, 9, nkeys).astype(np.float32)}
    cap = (rows // WORLD) * 2
    g = D.distribute_table(ctx, data, capacity_per_shard=cap)
    gr = D.distribute_table(ctx, right, capacity_per_shard=cap)

    out, dropped = M.chunked_dist_join(ctx, M.ChunkedTable(data, chunk),
                                       right, left_on=["k"],
                                       out_capacity_per_shard=1024,
                                       overcommit=4.0)
    assert dropped == 0
    mono, md = D.DistributedPipeline(
        ctx, lambda c, a, b: D.dist_join(c, a, b, left_on=["k"],
                                         out_capacity=1024,
                                         overcommit=4.0))(g, gr)
    assert int(np.max(np.asarray(md))) == 0
    mono = D.collect_table(ctx, mono)
    assert as_sets(out) == as_sets(mono)
    print(f"morsel join ok ({len(out['k'])} rows)")

    cg, cgd = M.chunked_dist_groupby(ctx, M.ChunkedTable(data, chunk),
                                     ["k"], {"v": ["sum", "mean"]},
                                     group_capacity_per_shard=nkeys,
                                     overcommit=4.0)
    assert cgd == 0
    mg, mgd = D.DistributedPipeline(
        ctx, lambda c, t: D.dist_groupby(c, t, ["k"],
                                         {"v": ["sum", "mean"]},
                                         overcommit=4.0))(g)
    assert int(np.max(np.asarray(mgd))) == 0
    mg = D.collect_table(ctx, mg)
    for k in mg:
        np.testing.assert_array_equal(cg[k], mg[k], err_msg=k)
    print("morsel groupby bit-identical ok")

    cs, csd = M.chunked_dist_sort(ctx, M.ChunkedTable(data, chunk), ["k"],
                                  overcommit=4.0)
    assert csd == 0
    ms, msd = D.DistributedPipeline(
        ctx, lambda c, t: D.dist_sort(c, t, ["k"], overcommit=4.0))(g)
    assert int(np.max(np.asarray(msd))) == 0
    ms = D.collect_table(ctx, ms)
    for k in ms:
        np.testing.assert_array_equal(cs[k], ms[k], err_msg=k)
    print("morsel sort bit-identical ok")


def check_empty_shards(ctx, rng):
    """Zero-row and fewer-rows-than-shards tables through the operators."""
    for n in (0, 3):                  # 3 rows over 8 shards: 5 empty
        data = {"k": rng.integers(0, 5, n).astype(np.int32),
                "v": rng.normal(size=n).astype(np.float32)}
        t = D.distribute_table(ctx, data, capacity_per_shard=8)
        v = D.distribute_table(ctx, data, capacity_per_shard=8)
        out, dropped = D.DistributedPipeline(
            ctx, lambda c, a, b: D.dist_join(
                c, a, b, left_on=["k"], out_capacity=64,
                overcommit=4.0))(t, v)
        assert int(np.max(np.asarray(dropped))) == 0
        t = D.distribute_table(ctx, data, capacity_per_shard=8)
        out, dropped = D.DistributedPipeline(
            ctx, lambda c, a: D.dist_groupby(c, a, ["k"], {"v": "sum"},
                                             overcommit=4.0))(t)
        assert int(np.max(np.asarray(dropped))) == 0
        got = D.collect_table(ctx, out)
        assert len(got["k"]) == len(np.unique(data["k"]))
        t = D.distribute_table(ctx, data, capacity_per_shard=8)
        out, dropped = D.DistributedPipeline(
            ctx, lambda c, a: D.dist_sort(c, a, ["k"],
                                          overcommit=4.0))(t)
        assert int(np.max(np.asarray(dropped))) == 0
        got = D.collect_table(ctx, out)
        np.testing.assert_array_equal(got["k"], np.sort(data["k"]))
    print("empty/sparse shards ok")


def check_repartition(ctx, rng):
    # skewed layout: all rows start on few shards
    data = {"a": np.arange(50, dtype=np.int32)}
    t = D.distribute_table(ctx, data, capacity_per_shard=50)
    pipe = D.DistributedPipeline(ctx,
                                 lambda c, a: D.dist_repartition(c, a))
    out, dropped = pipe(t)
    assert int(np.max(np.asarray(dropped))) == 0
    nv = np.asarray(out.nvalid).reshape(-1)
    # contract: no shard above the ceiling target (rank // ceil(N/W))
    assert nv.max() <= -(-50 // WORLD), nv
    assert nv.sum() == 50, nv
    got = D.collect_table(ctx, out)
    assert sorted(got["a"]) == list(range(50))
    print("dist_repartition ok")


def main():
    ctx = make_ctx()
    assert ctx.world_size == WORLD, ctx.world_size
    rng = np.random.default_rng(0)
    check_roundtrip(ctx, rng)
    check_join(ctx, rng, "sortmerge")
    check_join(ctx, rng, "hash")
    check_join_backends_agree(ctx, rng)
    check_join_planned(ctx, rng)
    check_groupby(ctx, rng)
    check_unique(ctx, rng)
    check_sort(ctx, rng, "xla")
    check_sort(ctx, rng, "radix")
    check_isin(ctx, rng, "sortmerge")
    check_isin(ctx, rng, "hash")
    check_setops(ctx, rng, "sortmerge")
    check_setops(ctx, rng, "hash")
    check_repartition(ctx, rng)
    check_morsel(ctx, rng)
    check_empty_shards(ctx, rng)
    print("DIST CHECKS PASSED")


if __name__ == "__main__":
    sys.exit(main())
