"""Subprocess worker for tests/test_sort_backends.py: distributed
sample-sort conformance at a given world size.

Usage: XLA_FLAGS=...device_count=W python sort_conformance.py W

For each key distribution x ascending flag, runs dist_sort with BOTH
local sort backends under one shard_map and checks (a) the backends are
bit-identical end to end (same splitters -> same routing -> same
shard-local order), (b) both match the pandas-semantics numpy oracle
*exactly* — the sample-sort is globally stable (shard order + stable
shuffle slots + stable local sort), so even tie order must match —
and (c) the dropped counter stays zero.  At world 4 a shard-skew
regression runs: one empty shard + full shards at capacity, default
overcommit, splitters must still partition with zero drops.  Prints
``SORT CONFORMANCE PASSED`` on success.
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
from oracles import np_sort_values  # noqa: E402


def distributions(rng, rows):
    return {
        "uniform": rng.integers(-500, 500, rows).astype(np.int32),
        "skewed": np.where(rng.random(rows) < 0.6, 3,
                           rng.integers(-40, 40, rows)).astype(np.int32),
        "allequal": np.full(rows, 7, np.int32),      # ties: stability
        "alldistinct": (rng.permutation(rows) - rows // 2)
        .astype(np.int32),
    }


def run_dist_sort(ctx, D, data, cap, ascending, impl, overcommit=4.0):
    gt = D.distribute_table(ctx, data, capacity_per_shard=cap)
    pipe = D.DistributedPipeline(
        ctx, lambda c, a: D.dist_sort(c, a, ["k"], ascending=ascending,
                                      overcommit=overcommit,
                                      local_impl=impl))
    out, dropped = pipe(gt)
    return out, dropped


def check_skew(ctx, D):
    """world 4, shards (3, 3, 3, 0): three full shards (at capacity), one
    empty.  Splitters must still partition exactly and nothing drops at
    the DEFAULT overcommit (2.0)."""
    # interleaved keys: each sender routes one row to each destination
    keys = np.array([0, 3, 6, 1, 4, 7, 2, 5, 8], np.int32)
    data = {"k": keys, "rid": np.arange(9, dtype=np.int32)}
    gt = D.distribute_table(ctx, data, capacity_per_shard=3)
    nv = np.asarray(gt.nvalid).reshape(-1)
    assert list(nv) == [3, 3, 3, 0], nv          # the skewed layout
    for impl in ("xla", "radix"):
        pipe = D.DistributedPipeline(
            ctx, lambda c, a, impl=impl: D.dist_sort(c, a, ["k"],
                                                     local_impl=impl))
        out, dropped = pipe(gt)
        assert int(np.max(np.asarray(dropped))) == 0, impl
        got = D.collect_table(ctx, out)
        np.testing.assert_array_equal(got["k"], np.arange(9),
                                      err_msg=impl)
        np.testing.assert_array_equal(got["rid"],
                                      np.argsort(keys, kind="stable"),
                                      err_msg=impl)
        # exact splitters (3, 6, sentinel): shards get 3/3/3/0 rows
        nv = np.asarray(out.nvalid).reshape(-1)
        assert list(nv) == [3, 3, 3, 0], (impl, nv)
    print("shard skew: ok", flush=True)


def main():
    world = int(sys.argv[1])
    import jax
    from jax.sharding import Mesh
    from repro.core import dist_ops as D
    from repro.core.context import make_context

    dev = np.array(jax.devices()[:world])
    ctx = make_context(Mesh(dev, ("data",)))
    rng = np.random.default_rng(world)
    rows = 96
    cap = (rows // world) * 4       # holds the allequal single-shard pile
    for name, keys in distributions(rng, rows).items():
        data = {"k": keys,
                "f": (rng.integers(-4, 5, rows) * 0.5).astype(np.float32),
                "rid": np.arange(rows, dtype=np.int32)}  # pins tie order
        for ascending in (True, False):
            got = {}
            for impl in ("xla", "radix"):
                out, dropped = run_dist_sort(ctx, D, data, cap, ascending,
                                             impl)
                assert int(np.max(np.asarray(dropped))) == 0, \
                    (name, ascending, impl)
                got[impl] = D.collect_table(ctx, out)
            for c in got["xla"]:
                np.testing.assert_array_equal(
                    got["xla"][c], got["radix"][c],
                    err_msg=f"{name}/asc={ascending}/{c}")
            want = np_sort_values(data, ["k"], ascending)
            for c in want:
                np.testing.assert_array_equal(
                    got["radix"][c], want[c].astype(got["radix"][c].dtype),
                    err_msg=f"{name}/asc={ascending} vs oracle {c}")
            print(f"{name}/asc={ascending}: ok", flush=True)
    if world == 4:
        check_skew(ctx, D)
    print("SORT CONFORMANCE PASSED")


if __name__ == "__main__":
    sys.exit(main())
