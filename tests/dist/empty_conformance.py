"""Subprocess worker for tests/test_empty_tables.py: zero-row and
empty-shard inputs through the distributed operators at a given world
size.

Usage: XLA_FLAGS=...device_count=W python empty_conformance.py W

Three degenerate shapes per operator:

* ``zero``: a 0-row table (every shard empty);
* ``sparse``: fewer rows than shards (trailing shards empty after the
  block distribution);
* one-sided emptiness for the binary ops (empty probe vs empty build).

Every leg asserts the dropped counter is zero and the collected result
matches the numpy oracle.  Prints ``EMPTY CONFORMANCE PASSED``.
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
from oracles import (as_sets, np_groupby_aggregate, np_isin, np_join,  # noqa: E402
                     np_sort_values)


def main():
    world = int(sys.argv[1])
    import jax
    from jax.sharding import Mesh
    from repro.core import dist_ops as D
    from repro.core.context import make_context

    dev = np.array(jax.devices()[:world])
    ctx = make_context(Mesh(dev, ("data",)))
    rng = np.random.default_rng(world)

    def dist(data, cap=32):
        return D.distribute_table(ctx, data, capacity_per_shard=cap)

    def run(fn, *tables):
        out, dropped = D.DistributedPipeline(ctx, fn)(*tables)
        assert int(np.max(np.asarray(dropped))) == 0
        return D.collect_table(ctx, out)

    zero = {"k": np.zeros(0, np.int32), "v": np.zeros(0, np.float32)}
    sparse = {"k": np.array([3, 1], np.int32),       # fewer rows than
              "v": np.array([1.0, 2.0], np.float32)}  # shards at world 4
    full = {"k": rng.integers(0, 4, 16).astype(np.int32),
            "v": rng.integers(0, 9, 16).astype(np.float32)}
    shapes = {"zero": zero, "sparse": sparse}

    for name, probe in shapes.items():
        # join: empty/sparse probe x full build, and full probe x empty build
        for how in ("inner", "left"):
            for lname, l, r in ((f"{name}-left", probe, full),
                                (f"{name}-right", full, probe)):
                got = run(lambda c, a, b, how=how: D.dist_join(
                    c, a, b, left_on=["k"], how=how, out_capacity=256),
                    dist(l), dist(r))
                lv = {"k": l["k"], "lv": l["v"]}
                rv = {"k": r["k"], "rv": r["v"]}
                want = np_join(lv, rv, how)
                got = {"k": got["k"], "lv": got["v"], "rv": got["v_r"]}
                assert as_sets(got) == as_sets(want), (lname, how)
        # groupby
        got = run(lambda c, t: D.dist_groupby(
            c, t, ["k"], {"v": ["sum", "mean", "count"]}), dist(probe))
        want = np_groupby_aggregate(probe, ["k"],
                                    {"v": ["sum", "mean", "count"]})
        assert as_sets(got) == as_sets(
            {k: np.asarray(v) for k, v in want.items()}), name
        # sort (shard order + local order == global order, even with
        # empty shards in between after range partition)
        got = run(lambda c, t: D.dist_sort(c, t, ["k"]), dist(probe))
        want = np_sort_values(probe, ["k"])
        for k in want:
            np.testing.assert_array_equal(got[k], want[k],
                                          err_msg=f"{name} sort {k}")
        # isin: empty/sparse table x full values, and full x empty/sparse
        for lname, t, v in ((f"{name}-tbl", probe, full),
                            (f"{name}-vals", full, probe)):
            got = run(lambda c, a, b: D.dist_isin(c, a, "k", b, "k"),
                      dist(t), dist(v))
            mask = np.asarray(np_isin(t, "k", v, "k"), dtype=bool)
            want = {k: np.asarray(col)[mask] for k, col in t.items()}
            assert as_sets(got) == as_sets(want), lname
        print(f"{name}: ok", flush=True)

    print("EMPTY CONFORMANCE PASSED")


if __name__ == "__main__":
    sys.exit(main())
