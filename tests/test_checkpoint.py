"""Checkpoint store: roundtrip, atomicity, gc, async writer."""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, all_steps, latest_step,
                              restore, save)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "opt": {"m": jnp.ones((8, 4)) * 0.5,
                    "step": jnp.int32(7)}}


def _trees_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(fa, fb))


def test_save_restore_roundtrip(tmp_path):
    state = _state()
    save(str(tmp_path), 10, state)
    step, restored = restore(str(tmp_path), state)
    assert step == 10
    assert _trees_equal(state, restored)


def test_latest_step_and_gc(tmp_path):
    state = _state()
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, state, keep_last=3)
    assert latest_step(str(tmp_path)) == 5
    assert all_steps(str(tmp_path)) == [3, 4, 5]


def test_restore_specific_step(tmp_path):
    s1 = _state(1)
    s2 = _state(2)
    save(str(tmp_path), 1, s1)
    save(str(tmp_path), 2, s2)
    step, got = restore(str(tmp_path), s1, step=1)
    assert step == 1
    assert _trees_equal(got, s1)


def test_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path), _state())


def test_crashed_tmp_dir_is_ignored(tmp_path):
    """A leftover .tmp_step dir (crashed writer) must not be listed."""
    state = _state()
    save(str(tmp_path), 1, state)
    os.makedirs(tmp_path / ".tmp_step_2")
    assert latest_step(str(tmp_path)) == 1
    # and a step dir without meta (partial rename impossible, but guard)
    os.makedirs(tmp_path / "step_99")
    assert latest_step(str(tmp_path)) == 1


def test_leaf_count_mismatch_asserts(tmp_path):
    save(str(tmp_path), 1, _state())
    with pytest.raises(AssertionError):
        restore(str(tmp_path), {"only": jnp.zeros(2)})


def test_async_checkpointer(tmp_path):
    ckpt = AsyncCheckpointer(str(tmp_path), keep_last=2)
    state = _state()
    for s in (10, 20, 30):
        ckpt.save(s, state)
    ckpt.wait()
    assert ckpt.last_saved == 30
    assert all_steps(str(tmp_path)) == [20, 30]
    _, got = restore(str(tmp_path), state)
    assert _trees_equal(got, state)


def test_async_checkpointer_snapshot_semantics(tmp_path):
    """State mutated after save() must not leak into the checkpoint."""
    ckpt = AsyncCheckpointer(str(tmp_path))
    state = {"w": np.ones(4, np.float32)}
    ckpt.save(1, {"w": jnp.asarray(state["w"])})
    ckpt.wait()
    _, got = restore(str(tmp_path), {"w": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(got["w"]), 1.0)


def test_restore_casts_to_template_sharding(tmp_path):
    """Restore device_puts against the template's sharding (single-device
    here; the elastic multi-mesh path is covered in tests/dist)."""
    state = {"w": jnp.arange(8, dtype=jnp.float32)}
    save(str(tmp_path), 1, state)
    template = {"w": jnp.zeros(8, jnp.float32)}
    _, got = restore(str(tmp_path), template)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(8))
    assert got["w"].dtype == jnp.float32
