"""Semi-join / set-operator backend conformance suite (hash == sortmerge
== pandas oracle).

The two local membership backends promise *drop-in identical* output:
``isin``/``semi_mask`` emit the same boolean mask, ``difference`` the
same rows in ``a``'s original order, ``intersect``/``union`` the same
canonical table (one row per distinct key, sorted by key, keep-first
payload) — bit-identical rows, order and dtypes.  This suite pins that
contract over key distributions x kernel impls, pins the promoted-dtype
comparison rule (a float32 3.7 probe must NOT match an int32 3 — the
seed's cast-to-values-dtype bug), checks the hash path's jaxpr carries
**no ``sort`` primitive**, checks the static-capacity overflow counters
trip exactly at slab capacity, and runs the distributed set ops at world
sizes 1/2/4 in subprocesses with forced host devices.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import kernel_backend, local_ops as L
from repro.core.table import Table

from oracles import np_difference, np_intersect, np_isin, np_union
from test_groupby_backends import _jaxpr_primitives, \
    assert_tables_identical

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)

ROWS = 48

DISTS = ["uniform", "skewed", "allequal", "alldistinct", "empty"]


def make_pair(dist: str, rng):
    """(a, b) dicts sharing the schema {'k','v'} with overlapping-but-not-
    equal key sets, per key distribution."""
    if dist == "uniform":
        ka = rng.integers(0, 12, ROWS)
        kb = rng.integers(6, 18, ROWS // 2)
    elif dist == "skewed":                     # one heavy key + sparse tail
        ka = np.where(rng.random(ROWS) < 0.6, 3,
                      rng.integers(0, 40, ROWS))
        kb = np.where(rng.random(ROWS // 2) < 0.5, 3,
                      rng.integers(20, 60, ROWS // 2))
    elif dist == "allequal":
        ka = np.full(ROWS, 7)
        kb = np.full(ROWS // 2, 7)
    elif dist == "alldistinct":
        ka = rng.permutation(ROWS)
        kb = rng.permutation(ROWS)[:ROWS // 2] + ROWS // 2
    else:                                      # empty probe side
        ka = np.zeros(0, np.int64)
        kb = rng.integers(0, 12, ROWS // 2)
    a = {"k": ka.astype(np.int32),
         "v": rng.integers(-100, 100, len(ka)).astype(np.float32)}
    b = {"k": kb.astype(np.int32),
         "v": rng.integers(-100, 100, len(kb)).astype(np.float32)}
    return a, b


def tables(a, b, pad=5):
    n_a = len(next(iter(a.values())))
    n_b = len(next(iter(b.values())))
    ta = Table.from_dict(a, capacity=max(n_a, 1) + pad)
    tb = Table.from_dict(b, capacity=max(n_b, 1) + pad)
    return ta, tb


@pytest.mark.parametrize("dist", DISTS)
@pytest.mark.parametrize("kernel_impl", ["ref", "pallas_interpret"])
def test_isin_backends_identical(dist, kernel_impl, rng):
    a, b = make_pair(dist, rng)
    ta, tb = tables(a, b)
    ms, s_over = L.isin(ta, "k", tb, "k", impl="sortmerge",
                        return_overflow=True)
    mh, h_over = L.isin(ta, "k", tb, "k", impl="hash",
                        return_overflow=True, kernel_impl=kernel_impl)
    assert int(s_over) == int(h_over) == 0
    np.testing.assert_array_equal(np.asarray(ms), np.asarray(mh),
                                  err_msg=f"isin {dist}")
    want = np_isin(a, "k", b, "k")
    np.testing.assert_array_equal(np.asarray(ms)[:len(a["k"])], want,
                                  err_msg=f"isin {dist} vs oracle")
    # padding rows are never members
    assert not np.asarray(ms)[len(a["k"]):].any()


@pytest.mark.parametrize("dist", DISTS)
@pytest.mark.parametrize("op", ["difference", "intersect", "union"])
def test_setop_backends_identical(dist, op, rng):
    a, b = make_pair(dist, rng)
    ta, tb = tables(a, b)
    if op == "union":
        # union's impl selects the *dedup* backend (sort | hash)
        s = L.union(ta, tb, on=["k"], impl="sort")
        h, over = L.union(ta, tb, on=["k"], impl="hash",
                          return_overflow=True)
        want = np_union(a, b, ["k"])
    else:
        fn = getattr(L, op)
        s = fn(ta, tb, on=["k"], impl="sortmerge")
        h, over = fn(ta, tb, on=["k"], impl="hash", return_overflow=True)
        want = (np_difference if op == "difference"
                else np_intersect)(a, b, ["k"])
    assert int(over) == 0
    assert int(s.nvalid) == int(h.nvalid)
    assert_tables_identical(s.to_numpy(), h.to_numpy(), f"{op} {dist}")
    got = h.to_numpy()
    for c in want:
        np.testing.assert_array_equal(got[c], want[c].astype(got[c].dtype),
                                      err_msg=f"{op} {dist} col={c}")


def test_isin_promoted_dtype_no_false_positives(rng):
    """The seed bug: isin cast the query column to the values column's
    dtype, so a float32 3.7 probe truncated to int32 3 and matched.  Both
    backends must now compare in the promoted common dtype: 3.7 is NOT a
    member, 3.0 IS."""
    q = Table.from_dict({"x": np.array([3.7, 3.0, -1.5, 2.0],
                                       np.float32)}, capacity=6)
    vals = Table.from_dict({"y": np.array([3, 2, 9], np.int32)},
                           capacity=4)
    want = np.array([False, True, False, True])
    for impl in ("sortmerge", "hash"):
        got = np.asarray(L.isin(q, "x", vals, "y", impl=impl))[:4]
        np.testing.assert_array_equal(got, want, err_msg=impl)
    # and the mirrored direction: int probe vs float values — int 3
    # matches 3.0 but nothing matches 3.5
    q2 = Table.from_dict({"x": np.array([3, 4], np.int32)}, capacity=4)
    v2 = Table.from_dict({"y": np.array([3.0, 3.5], np.float32)},
                         capacity=4)
    for impl in ("sortmerge", "hash"):
        got = np.asarray(L.isin(q2, "x", v2, "y", impl=impl))[:2]
        np.testing.assert_array_equal(got, [True, False], err_msg=impl)


def test_multi_and_mixed_dtype_keys(rng):
    """int32 + float32 key columns, compared pairwise in promoted dtype:
    both backends agree bit-identically and with the oracle."""
    n = 40
    a = {"ik": rng.integers(0, 4, n).astype(np.int32),
         "fk": (rng.integers(-3, 4, n) * 0.5).astype(np.float32),
         "v": rng.integers(-50, 50, n).astype(np.float32)}
    b = {"ik": rng.integers(0, 4, n // 2).astype(np.int32),
         "fk": (rng.integers(-3, 4, n // 2) * 0.5).astype(np.float32),
         "v": rng.integers(-50, 50, n // 2).astype(np.float32)}
    ta, tb = tables(a, b)
    on = ["ik", "fk"]
    ms = L.semi_mask(ta, tb, on, impl="sortmerge")
    mh = L.semi_mask(ta, tb, on, impl="hash")
    np.testing.assert_array_equal(np.asarray(ms), np.asarray(mh))
    for op, oracle in (("difference", np_difference),
                       ("intersect", np_intersect)):
        s = getattr(L, op)(ta, tb, on=on, impl="sortmerge")
        h = getattr(L, op)(ta, tb, on=on, impl="hash")
        assert_tables_identical(s.to_numpy(), h.to_numpy(), op)
        want = oracle(a, b, on)
        got = h.to_numpy()
        for c in want:
            np.testing.assert_array_equal(
                got[c], want[c].astype(got[c].dtype),
                err_msg=f"mixed {op} col={c}")


def test_union_respects_key_subset_and_tie_order(rng):
    """The seed's union had no ``on=``: dedup ran over ALL columns, so
    rows equal on the key but different in payload both survived.  With
    ``on=`` the output has one row per key, payload from the key's first
    occurrence — ``a``'s rows win ties against ``b``'s."""
    a = {"k": np.array([1, 2], np.int32),
         "v": np.array([10., 20.], np.float32)}
    b = {"k": np.array([2, 3], np.int32),
         "v": np.array([99., 30.], np.float32)}
    ta, tb = tables(a, b)
    for impl in ("sort", "hash"):
        u = L.union(ta, tb, on=["k"], impl=impl).to_numpy()
        np.testing.assert_array_equal(u["k"], [1, 2, 3], err_msg=impl)
        np.testing.assert_array_equal(u["v"], [10., 20., 30.],
                                      err_msg=impl)  # a's v=20 wins
    # backward compat: no on= dedups full rows, both (2,20) and (2,99) stay
    full = L.union(ta, tb).to_numpy()
    assert len(full["k"]) == 4


def test_union_counts_overflow(rng):
    """Union overflow is counted, never silent: all-equal keys with a slab
    smaller than the group trip the dedup backend's counter."""
    n = 16
    a = {"k": np.full(n, 1, np.int32),
         "v": np.arange(n, dtype=np.float32)}
    b = {"k": np.full(n, 1, np.int32),
         "v": np.arange(n, dtype=np.float32)}
    ta, tb = tables(a, b, pad=0)
    u, over = L.union(ta, tb, on=["k"], impl="hash", return_overflow=True,
                      num_buckets=4, bucket_capacity=8)
    assert int(u.nvalid) == 1
    assert int(over) == 2 * n - 8


def test_semi_overflow_counters_trip_at_capacity():
    """All-equal keys with slabs smaller than the group: build and probe
    overflow are both counted; a probe-dropped row reports non-member
    (excluded from difference's complement too — it is counted, not
    guessed)."""
    n = 24
    t = Table.from_dict({"k": np.full(n, 1, np.int32)}, capacity=n)
    vals = Table.from_dict({"k": np.full(4, 1, np.int32)}, capacity=4)
    # probe side overflows: only probe_capacity probes fit the slab
    mask, over = L.isin(t, "k", vals, "k", impl="hash", num_buckets=4,
                        probe_capacity=8, return_overflow=True)
    assert int(over) == n - 8
    assert int(np.asarray(mask).sum()) == 8
    # build side overflows: members still resolve from surviving builds
    mask, over = L.isin(vals, "k", t, "k", impl="hash", num_buckets=4,
                        bucket_capacity=8, return_overflow=True)
    assert int(over) == n - 8
    assert int(np.asarray(mask).sum()) == 4


def test_cartesian_product_counts_overflow(rng):
    """The seed bug: cartesian_product clamped ``nvalid`` to the output
    capacity with no signal that rows were lost."""
    a = Table.from_dict({"k": np.arange(4, dtype=np.int32)}, capacity=4)
    b = Table.from_dict({"j": np.arange(3, dtype=np.int32)}, capacity=4)
    out, over = L.cartesian_product(a, b, out_capacity=8,
                                    return_overflow=True)
    assert int(out.nvalid) == 8
    assert int(over) == 4            # 4*3 = 12 pairs, 8 slots
    out2, over2 = L.cartesian_product(a, b, out_capacity=16,
                                      return_overflow=True)
    assert int(out2.nvalid) == 12
    assert int(over2) == 0
    # default signature unchanged (no tuple)
    assert isinstance(L.cartesian_product(a, b, out_capacity=8), Table)


@pytest.mark.parametrize("capacity", [ROWS + 5, 4096],
                         ids=["small", "above_exact_slab"])
def test_hash_path_contains_no_sort_primitive(capacity, rng):
    """The acceptance contract: the hash semi backend replaces the
    sort-based membership entirely — its jaxpr must not contain `sort`,
    at small capacities (full-capacity slabs) AND above ``EXACT_SLAB_CAP``
    where auto-sizing switches to the bucket-count heuristic."""
    a, b = make_pair("uniform", rng)
    ta = Table.from_dict(a, capacity=capacity)
    tb = Table.from_dict(b, capacity=capacity)
    prims = _jaxpr_primitives(
        lambda x, y: L.isin(x, "k", y, "k", impl="hash"), ta, tb)
    assert "sort" not in prims, sorted(prims)
    prims = _jaxpr_primitives(
        lambda x, y: L.difference(x, y, on=["k"], impl="hash"), ta, tb)
    assert "sort" not in prims, sorted(prims)
    prims = _jaxpr_primitives(
        lambda x, y: L.intersect(x, y, on=["k"], impl="hash",
                                 dedup_impl="hash"), ta, tb)
    assert "sort" not in prims, sorted(prims)
    # the sortmerge backend, for contrast, does sort — unless the radix
    # engine is the session default, which makes even that path sort-free
    prims = _jaxpr_primitives(
        lambda x, y: L.isin(x, "k", y, "k", impl="sortmerge"), ta, tb)
    if kernel_backend.sort_impl() == "xla":
        assert "sort" in prims
    else:
        assert "sort" not in prims, sorted(prims)


def test_env_default_backend(monkeypatch, rng):
    a, b = make_pair("uniform", rng)
    ta, tb = tables(a, b)
    monkeypatch.setenv("REPRO_SEMI_IMPL", "hash")
    assert kernel_backend.semi_impl() == "hash"
    mh = np.asarray(L.isin(ta, "k", tb, "k"))
    monkeypatch.setenv("REPRO_SEMI_IMPL", "sortmerge")
    ms = np.asarray(L.isin(ta, "k", tb, "k"))
    np.testing.assert_array_equal(ms, mh, err_msg="env dispatch")
    with pytest.raises(ValueError):
        L.isin(ta, "k", tb, "k", impl="nope")
    with pytest.raises(ValueError):
        L.difference(ta, tb, on=["k"], impl="nope")


def test_join_backends_promote_mixed_key_dtypes(rng):
    """The promoted-dtype rule extends to the join backends: a float32
    3.7 probe must not join an int32 3 build row, and both backends must
    agree."""
    left = Table.from_dict({"k": np.array([3.7, 3.0], np.float32),
                            "lv": np.array([0., 1.], np.float32)},
                           capacity=4)
    right = Table.from_dict({"k": np.array([3], np.int32),
                             "rv": np.array([7.], np.float32)},
                            capacity=2)
    for impl in ("sortmerge", "hash"):
        out = L.join(left, right, left_on=["k"], out_capacity=8,
                     impl=impl).to_numpy()
        assert len(out["k"]) == 1, impl
        assert out["lv"][0] == 1.0, impl     # only the 3.0 row joined


@pytest.mark.parametrize("world", [1, 2, 4])
def test_dist_setop_conformance(world):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={world}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable,
         os.path.join(HERE, "dist", "setop_conformance.py"), str(world)],
        env=env, capture_output=True, text=True, timeout=1800)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, \
        f"setop conformance failed (world={world})"
    assert "SETOP CONFORMANCE PASSED" in proc.stdout


def test_fused_semi_plan_three_scatters():
    """One stacked scatter per slab family on the fused bucketing path:
    build slabs, probe slabs, packed member/probed result — exactly three
    ``scatter`` eqns in the semi plan's jaxpr."""
    import jax.numpy as jnp
    from repro.kernels.hash_semi import hash_semi_plan
    from test_groupby_backends import _count_scatter_eqns
    n = 64
    bits = (jnp.arange(n, dtype=jnp.int32),)
    valid = jnp.ones((n,), bool)
    cnt = _count_scatter_eqns(
        lambda b, v: hash_semi_plan(b, v, b, v, num_buckets=8,
                                    bucket_capacity=16, probe_capacity=16,
                                    impl="ref"), bits, valid)
    assert cnt == 3, cnt


@pytest.mark.parametrize("op", ["isin", "difference", "intersect"])
def test_hash_semi_key_bits_once_per_side(op, monkeypatch, rng):
    """BucketPlan extracts the key bit-planes exactly once per side and
    shares them between the sizing pass and the build/probe kernel plan
    — no re-hash between build and probe of the same columns."""
    from repro.kernels import bucketing
    calls = []
    real = bucketing.key_bits

    def counting(col):
        calls.append(col.shape)
        return real(col)

    monkeypatch.setattr(bucketing, "key_bits", counting)
    a, b = make_pair("uniform", rng)
    ta, tb = tables(a, b)
    if op == "isin":
        L.isin(ta, "k", tb, "k", impl="hash")
        expect = 2                     # probe side + values side
    elif op == "difference":
        L.difference(ta, tb, ["k"], impl="hash")
        expect = 2
    else:
        L.intersect(ta, tb, ["k"], impl="hash", dedup_impl="hash")
        expect = 3                     # semi (2 sides) + key-only dedup
    assert len(calls) == expect, calls
