"""Serving engine tests (repro/serving/).

Host-side unit tests for the admission queue (rejections counted at
capacity, never silent), the fixed-shape slot batcher, and the metrics
registry; engine-level tests on the reduced LM config (single jit trace
across heterogeneous request sizes, static batch shape across refills,
greedy-decode conformance against the one-shot serve path, feature
fusion and the accounting identity); a reduced-config e2e smoke through
the ``repro.launch.serve`` CLI with a pre-set ``XLA_FLAGS`` (the
append-merge re-exec fix); and world 2/4 subprocess conformance for the
feature-fetch path.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core.context import make_context
from repro.models import model as M
from repro.serving import (AdmissionQueue, FeatureStore, Request,
                           ServingEngine, ServingMetrics, SlotBatch)

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)


# --------------------------------------------------------------------------
# admission queue: counted rejections
# --------------------------------------------------------------------------


def test_queue_rejects_counted_at_capacity():
    m = ServingMetrics()
    q = AdmissionQueue(2, m)
    assert q.offer("a") and q.offer("b")
    assert not q.offer("c")          # full: refused, counted
    assert not q.offer("d")
    assert m.count("submitted") == 4
    assert m.count("rejected") == 2
    assert len(q) == 2
    assert q.pop() == "a"            # FIFO
    assert q.offer("e")              # freed capacity admits again
    assert m.count("rejected") == 2
    # identity: everything offered is accounted for
    assert m.count("submitted") == len(q) + 1 + m.count("rejected")


def test_queue_validates_capacity():
    with pytest.raises(ValueError, match="capacity"):
        AdmissionQueue(0)
    assert AdmissionQueue(1).pop() is None


# --------------------------------------------------------------------------
# slot batcher: static shapes, refill semantics
# --------------------------------------------------------------------------


def test_slot_batch_lifecycle():
    b = SlotBatch(3)
    assert b.free() == [0, 1, 2] and b.occupancy == 0
    b.occupy(1, "r1", first_token=7, prompt_len=4, gen_target=2)
    assert b.active() == [1] and b.cache_lens[1] == 4 and b.tokens[1, 0] == 7
    with pytest.raises(ValueError, match="occupied"):
        b.occupy(1, "r2", first_token=0, prompt_len=1, gen_target=1)
    nxt = np.zeros((3, 1), np.int32)
    nxt[1, 0] = 9
    seen = []
    done = b.advance(nxt, on_token=lambda s, r, t: seen.append((s, r, t)))
    assert done == [1] and seen == [(1, "r1", 9)]       # hit gen_target=2
    assert b.cache_lens[1] == 5 and b.tokens[1, 0] == 9
    assert b.release(1) == "r1" and b.free() == [0, 1, 2]
    with pytest.raises(ValueError, match="free"):
        b.release(1)
    # shapes never change across occupy/release cycles
    assert b.cache_lens.shape == (3,) and b.tokens.shape == (3, 1)


def test_slot_batch_advance_skips_idle_slots():
    b = SlotBatch(2)
    b.occupy(0, "r", first_token=1, prompt_len=2, gen_target=5)
    before = b.cache_lens.copy()
    b.advance(np.zeros((2, 1), np.int32))
    assert b.cache_lens[1] == before[1]      # idle slot untouched
    assert b.cache_lens[0] == before[0] + 1


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------


def test_metrics_registry():
    m = ServingMetrics()
    m.inc("x"), m.inc("x", 2)
    m.gauge("g", 3), m.gauge("g", 1)
    for v in (0.1, 0.2, 0.3):
        m.observe("lat", v)
    assert m.count("x") == 3 and m.count("missing") == 0
    assert m.gauges["g"] == {"last": 1.0, "max": 3.0}
    s = m.summary("lat")
    assert s["count"] == 3 and abs(s["p50"] - 0.2) < 1e-9
    snap = m.snapshot()
    assert snap["counters"]["x"] == 3 and "lat" in snap["latency"]
    assert m.summary("none") == {"count": 0}
    assert np.isnan(m.percentile("none", 50))


# --------------------------------------------------------------------------
# engine on the reduced config
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    """One engine run over heterogeneous requests + feature stores;
    several tests assert different properties of the same run."""
    cfg = get_reduced("lm100m")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ctx = make_context(jax.make_mesh((1,), ("rows",)))
    n_keys = 32
    rng = np.random.default_rng(1)
    feats = {"drug_id": np.arange(n_keys, dtype=np.int32),
             "d0": rng.normal(size=n_keys).astype(np.float32)}
    store = FeatureStore(ctx, "drug_id", feats, probe_capacity=8,
                         chunk_rows=8)
    eng = ServingEngine(cfg, params, slots=2, prompt_capacity=12,
                        gen_capacity=6, queue_capacity=4,
                        feature_stores={"drug_id": store})
    reqs = []
    # heterogeneous prompt lengths and gen lengths, incl. the gen_len=1
    # immediate-completion edge and one key with no feature row
    for i, (p_len, g) in enumerate([(12, 6), (1, 1), (5, 3), (9, 2),
                                    (3, 4), (7, 1), (2, 5), (11, 3)]):
        reqs.append(Request(
            req_id=i, prompt=rng.integers(0, cfg.vocab, p_len
                                          ).astype(np.int32),
            gen_len=g, drug_id=(999 if i == 3 else i)))
    rejected = [r for r in reqs if not eng.submit(r)]
    done = eng.run_until_drained()
    # resubmit anything rejected by the small queue (accounted above)
    for r in rejected:
        assert eng.submit(r)
    done += eng.run_until_drained()
    return eng, store, feats, reqs, rejected, done


def test_engine_every_admitted_request_completes(served):
    eng, store, feats, reqs, rejected, done = served
    m = eng.metrics
    assert m.count("submitted") == m.count("completed") + \
        m.count("rejected") + m.count("feature_misses")
    assert m.count("rejected") == len(rejected)
    by_id = {r.req_id: r for r in done}
    assert sorted(by_id) == list(range(len(reqs)))   # nobody lost
    for r in done:
        if r.req_id == 3:
            assert r.status == "feature_miss"        # counted terminal
        else:
            assert r.status == "done"
            assert len(r.out_tokens) == r.gen_len
            np.testing.assert_allclose(
                r.features["d0"], feats["d0"][r.drug_id])   # joined row
    assert m.count("feature_misses") == 1
    assert store.dropped == 0


def test_engine_one_trace_across_heterogeneous_requests(served):
    eng, *_ = served
    # every prompt length / gen length re-entered the same cached
    # executables: fixed padded prefill shape, fixed decode batch shape
    assert eng._prefill._cache_size() == 1
    assert eng._decode._cache_size() == 1
    assert eng._insert._cache_size() == 1


def test_engine_static_batch_shape_across_refills(served):
    eng, *_ = served
    struct = M.cache_struct(eng.cfg, eng.n_slots, eng.decode_len)
    got = jax.tree_util.tree_map(lambda x: x.shape, eng.caches)
    want = jax.tree_util.tree_map(lambda s: s.shape, struct)
    assert got == want


def test_engine_validates_request_bounds(served):
    eng, *_ = served
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit(Request(req_id=99, prompt=np.zeros(13, np.int32),
                           gen_len=1, drug_id=0))
    with pytest.raises(ValueError, match="gen_len"):
        eng.submit(Request(req_id=99, prompt=np.zeros(1, np.int32),
                           gen_len=7, drug_id=0))


def test_engine_matches_oneshot_greedy_decode():
    """A request decoded through slot refill + per-slot cache lengths
    emits the same greedy tokens as the one-shot prefill/serve path."""
    cfg = get_reduced("lm100m")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    P, G = 10, 5
    rng = np.random.default_rng(2)
    for p_len in (P, 4):             # full-capacity and right-padded
        prompt = rng.integers(0, cfg.vocab, p_len).astype(np.int32)

        # reference: exact-length one-shot decode (launch/serve's loop)
        prefill = jax.jit(M.make_prefill(cfg, None, decode_len=P + G))
        serve = jax.jit(M.make_serve_step(cfg, None))
        logits, caches = prefill(params, {"tokens": jnp.asarray(
            prompt[None])})
        tok = int(jnp.argmax(logits, -1)[0])
        want = [tok]
        for i in range(G - 1):
            logits, caches = serve(params, caches,
                                   jnp.asarray([[tok]], jnp.int32),
                                   jnp.int32(p_len + i))
            tok = int(jnp.argmax(logits, -1)[0])
            want.append(tok)

        eng = ServingEngine(cfg, params, slots=3, prompt_capacity=P,
                            gen_capacity=G, queue_capacity=4)
        req = Request(req_id=0, prompt=prompt, gen_len=G)
        assert eng.submit(req)
        done = eng.run_until_drained()
        assert done[0].out_tokens == want, f"p_len={p_len}"


def test_engine_rejects_nonlm_config():
    import dataclasses
    cfg = dataclasses.replace(get_reduced("lm100m"), frontend="vision")
    with pytest.raises(ValueError, match="decoder-only"):
        ServingEngine(cfg, params={}, slots=1)


def test_feature_store_validation():
    ctx = make_context(jax.make_mesh((1,), ("rows",)))
    with pytest.raises(ValueError, match="probe_capacity"):
        FeatureStore(ctx, "k", {"k": np.arange(4)}, probe_capacity=0)
    with pytest.raises(ValueError, match="key column"):
        FeatureStore(ctx, "nope", {"k": np.arange(4)}, probe_capacity=4)
    store = FeatureStore(ctx, "k", {"k": np.arange(4)}, probe_capacity=4)
    with pytest.raises(ValueError, match="exceed"):
        store.lookup(np.zeros(5, np.int32))
    with pytest.raises(ValueError, match="1-D"):
        store.lookup(np.zeros((2, 2), np.int32))


# --------------------------------------------------------------------------
# e2e smoke through the CLI (XLA_FLAGS preset: the append-merge fix)
# --------------------------------------------------------------------------


def test_serve_cli_e2e_reduced_with_preset_xla_flags():
    env = dict(os.environ)
    # pre-existing unrelated XLA flag: the launcher must append the
    # device-count flag (the old code skipped re-exec and crashed the
    # mesh build); a stale count must be replaced, then terminate
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1 " \
                       "--xla_cpu_enable_fast_math=false"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "lm100m",
         "--reduced", "--requests", "6", "--slots", "2", "--prompt-len",
         "8", "--gen", "4", "--queue-capacity", "8",
         "--mesh", "data=1,model=2"],
        env=env, capture_output=True, text=True, timeout=1800)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0
    assert "serve OK" in proc.stdout


# --------------------------------------------------------------------------
# world 2/4 feature-fetch conformance (subprocess, forced host devices)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("world", [2, 4])
def test_serving_feature_conformance(world):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={world}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable,
         os.path.join(HERE, "dist", "serving_conformance.py"), str(world)],
        env=env, capture_output=True, text=True, timeout=1800)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, f"serving conformance failed " \
                                 f"(world={world})"
    assert "SERVING CONFORMANCE PASSED" in proc.stdout
