"""GroupBy/Aggregate backend conformance suite (hash == sort == pandas
oracle).

The two local aggregation backends promise *drop-in identical* output —
the canonical table: one row per distinct key, rows sorted by the key
columns, counts int32, value aggregates float32.  This suite pins that
contract over key distributions x agg sets x kernel impls, checks the
hash path's jaxpr carries **no ``sort`` primitive**, checks the
static-capacity overflow counter trips exactly at bucket capacity, and
runs the distributed groupby/unique/standard-scale at world sizes 1/2/4
in subprocesses with forced host devices.

Value columns are *integer-valued* floats: float addition is then exact
in any association, so even ``sum``/``mean`` are bit-identical across
backends (the canonicalization contract: with arbitrary floats the
backends agree to addition-order rounding — see kernels/README.md).
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import kernel_backend, local_ops as L
from repro.core.table import Table

from oracles import np_drop_duplicates, np_groupby_aggregate, \
    np_standard_scale

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)

ROWS = 48

DISTS = ["uniform", "skewed", "allequal", "alldistinct", "empty"]

AGG_SETS = [
    {"v": ["sum", "count"]},
    {"v": ["mean", "min", "max"], "w": "sum"},
    {"v": ["sum", "count", "mean", "min", "max"],
     "w": ["min", "count"]},
]


def make_data(dist: str, rng) -> dict:
    if dist == "uniform":
        k = rng.integers(0, 12, ROWS)
    elif dist == "skewed":                     # one heavy key + sparse tail
        k = np.where(rng.random(ROWS) < 0.6, 3,
                     rng.integers(0, 40, ROWS))
    elif dist == "allequal":
        k = np.full(ROWS, 7)
    elif dist == "alldistinct":
        k = rng.permutation(ROWS)
    else:                                      # empty
        k = np.zeros(0, np.int64)
    n = len(k)
    return {"k": k.astype(np.int32),
            # integer-valued floats -> exact sums in any addition order
            "v": rng.integers(-100, 100, n).astype(np.float32),
            "w": rng.integers(0, 50, n).astype(np.float32)}


def assert_tables_identical(a: dict, b: dict, msg=""):
    assert set(a) == set(b), msg
    for c in a:
        assert a[c].dtype == b[c].dtype, f"{msg} col={c} dtype"
        np.testing.assert_array_equal(a[c], b[c], err_msg=f"{msg} col={c}")


def run_both(t: Table, by, aggs, kernel_impl="ref"):
    s, s_over = L.groupby_aggregate(t, by, aggs, impl="sort",
                                    return_overflow=True)
    h, h_over = L.groupby_aggregate(t, by, aggs, impl="hash",
                                    return_overflow=True,
                                    kernel_impl=kernel_impl)
    assert int(s_over) == int(h_over) == 0
    assert int(s.nvalid) == int(h.nvalid)
    return s, h


@pytest.mark.parametrize("dist", DISTS)
@pytest.mark.parametrize("aggs", AGG_SETS, ids=["sum_count", "mmm_wsum",
                                                "all_aggs"])
@pytest.mark.parametrize("kernel_impl", ["ref", "pallas_interpret"])
def test_local_backends_identical(dist, aggs, kernel_impl, rng):
    data = make_data(dist, rng)
    t = Table.from_dict(data, capacity=max(len(data["k"]), 1) + 5)
    s, h = run_both(t, ["k"], aggs, kernel_impl)
    assert_tables_identical(s.to_numpy(), h.to_numpy(), f"{dist}")
    want = np_groupby_aggregate(data, ["k"], aggs)
    got = h.to_numpy()
    assert set(got) == set(want)
    for c in want:
        # integer-valued data: exact agreement with the float64 oracle
        np.testing.assert_array_equal(
            got[c].astype(np.float64), want[c].astype(np.float64),
            err_msg=f"{dist} vs oracle col={c}")
    if "v_count" in got:
        assert got["v_count"].dtype == np.int32


def test_multi_and_mixed_dtype_keys(rng):
    """int32 + float32 key columns: bit-plane equality and the pairwise
    canonical rank must match the sort backend's lexicographic order."""
    n = 40
    data = {"ik": rng.integers(0, 4, n).astype(np.int32),
            "fk": (rng.integers(-3, 4, n) * 0.5).astype(np.float32),
            "v": rng.integers(-50, 50, n).astype(np.float32)}
    t = Table.from_dict(data, capacity=n + 3)
    aggs = {"v": ["sum", "count", "mean", "min", "max"]}
    s, h = run_both(t, ["ik", "fk"], aggs)
    assert_tables_identical(s.to_numpy(), h.to_numpy(), "mixed keys")
    want = np_groupby_aggregate(data, ["ik", "fk"], aggs)
    got = h.to_numpy()
    for c in want:
        np.testing.assert_array_equal(got[c].astype(np.float64),
                                      want[c].astype(np.float64),
                                      err_msg=f"mixed keys col={c}")


@pytest.mark.parametrize("dist", DISTS)
def test_dedup_backends_identical(dist, rng):
    data = make_data(dist, rng)
    t = Table.from_dict(data, capacity=max(len(data["k"]), 1) + 4)
    ds = L.drop_duplicates(t, ["k"], impl="sort")
    dh, over = L.drop_duplicates(t, ["k"], impl="hash",
                                 return_overflow=True)
    assert int(over) == 0
    assert_tables_identical(ds.to_numpy(), dh.to_numpy(), f"dedup {dist}")
    want = np_drop_duplicates(data, ["k"])
    got = dh.to_numpy()
    for c in want:   # payload rows come from each key's FIRST occurrence
        np.testing.assert_array_equal(got[c], want[c].astype(got[c].dtype),
                                      err_msg=f"dedup {dist} col={c}")


def test_standard_scale_impls_agree(rng):
    data = {"x": rng.normal(size=50).astype(np.float32),
            "y": rng.normal(size=50).astype(np.float32)}
    t = Table.from_dict(data, capacity=64)
    want = np_standard_scale(data, ["x", "y"])
    for impl in (None, "sort", "hash"):
        got = L.standard_scale(t, ["x", "y"], impl=impl).to_numpy()
        for c in ("x", "y"):
            np.testing.assert_allclose(got[c], want[c], rtol=1e-4,
                                       atol=1e-4, err_msg=f"{impl}/{c}")


def test_standard_scale_large_mean_is_stable(rng):
    """|mean| >> std: the two-pass variance must not cancel (the one-pass
    E[x^2] - m^2 form collapses to ~0 variance in float32 here and blows
    the scaled values up ~1e3x)."""
    x = (16000.0 + 0.1 * rng.normal(size=64)).astype(np.float32)
    t = Table.from_dict({"x": x}, capacity=64)
    for impl in (None, "sort", "hash"):
        got = L.standard_scale(t, ["x"], impl=impl).to_numpy()["x"]
        assert np.isfinite(got).all(), impl
        np.testing.assert_allclose(got.std(), 1.0, atol=0.05,
                                   err_msg=str(impl))
        np.testing.assert_allclose(got.mean(), 0.0, atol=0.05,
                                   err_msg=str(impl))


def _jaxpr_primitives(fn, *args):
    prims = set()

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            prims.add(eqn.primitive.name)
            for v in eqn.params.values():
                for x in (v if isinstance(v, (list, tuple)) else (v,)):
                    if hasattr(x, "jaxpr"):
                        walk(x.jaxpr)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return prims


def _count_scatter_eqns(fn, *args) -> int:
    """Number of ``scatter`` eqns (``.at[].set``) anywhere in the jaxpr —
    the fused-bucketing regression pin: one stacked scatter per slab
    family, not one scatter per column."""
    n = 0

    def walk(jaxpr):
        nonlocal n
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "scatter":
                n += 1
            for v in eqn.params.values():
                for x in (v if isinstance(v, (list, tuple)) else (v,)):
                    if hasattr(x, "jaxpr"):
                        walk(x.jaxpr)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return n


def test_fused_groupby_plan_single_scatter():
    """The fused bucketing path writes ALL slab columns — key planes,
    occupancy, row ids and every value payload — with ONE stacked
    scatter: the groupby plan's jaxpr carries exactly one ``scatter``
    eqn, however many value columns ride along."""
    import jax.numpy as jnp
    from repro.kernels.hash_groupby import hash_groupby_plan
    n = 64
    bits = (jnp.arange(n, dtype=jnp.int32),)
    valid = jnp.ones((n,), bool)
    vals = (jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32))
    cnt = _count_scatter_eqns(
        lambda b, v, w: hash_groupby_plan(b, v, w, num_buckets=8,
                                          bucket_capacity=16, impl="ref"),
        bits, valid, vals)
    assert cnt == 1, cnt


@pytest.mark.parametrize("capacity", [ROWS + 5, 4096],
                         ids=["small", "above_exact_slab"])
def test_hash_path_contains_no_sort_primitive(capacity, rng):
    """The acceptance contract: the hash backend replaces the sort-based
    groupby/dedup entirely — its jaxpr must not contain `sort`, at small
    capacities (full-capacity slabs) AND above ``EXACT_SLAB_CAP`` where
    auto-sizing switches to the bucket-count heuristic (which must stay
    within the radix ranking's sort-free range)."""
    data = make_data("uniform", rng)
    t = Table.from_dict(data, capacity=capacity)
    aggs = {"v": ["sum", "count", "mean", "min", "max"]}
    prims = _jaxpr_primitives(
        lambda tt: L.groupby_aggregate(tt, ["k"], aggs, impl="hash"), t)
    assert "sort" not in prims, sorted(prims)
    prims = _jaxpr_primitives(
        lambda tt: L.drop_duplicates(tt, ["k"], impl="hash"), t)
    assert "sort" not in prims, sorted(prims)
    # the sort backend, for contrast, does sort — unless the radix sort
    # engine is the session default, which makes even this path sort-free
    prims = _jaxpr_primitives(
        lambda tt: L.groupby_aggregate(tt, ["k"], aggs, impl="sort"), t)
    if kernel_backend.sort_impl() == "xla":
        assert "sort" in prims
    else:
        assert "sort" not in prims, sorted(prims)


def test_overflow_counter_trips_at_capacity():
    """All-equal keys with a bucket slab smaller than the group: surviving
    rows aggregate exactly, the rest are counted as dropped."""
    n = 24
    t = Table.from_dict({"k": np.full(n, 1, np.int32),
                         "v": np.arange(n, dtype=np.float32)},
                        capacity=n)
    out, over = L.groupby_aggregate(t, ["k"], {"v": ["sum", "count"]},
                                    impl="hash", return_overflow=True,
                                    num_buckets=4, bucket_capacity=8)
    assert int(out.nvalid) == 1
    assert int(over) == n - 8
    got = out.to_numpy()
    # slabs keep original row order: the first 8 rows survive
    assert got["v_count"][0] == 8
    assert got["v_sum"][0] == float(np.arange(8).sum())
    # dedup counts the same overflow
    dd, over = L.drop_duplicates(t, ["k"], impl="hash",
                                 return_overflow=True, num_buckets=4,
                                 bucket_capacity=8)
    assert int(dd.nvalid) == 1
    assert int(over) == n - 8


def test_env_default_backend(monkeypatch, rng):
    data = make_data("uniform", rng)
    t = Table.from_dict(data, capacity=ROWS)
    monkeypatch.setenv("REPRO_GROUPBY_IMPL", "hash")
    assert kernel_backend.groupby_impl() == "hash"
    h = L.groupby_aggregate(t, ["k"], {"v": "sum"})
    monkeypatch.setenv("REPRO_GROUPBY_IMPL", "sort")
    s = L.groupby_aggregate(t, ["k"], {"v": "sum"})
    assert_tables_identical(s.to_numpy(), h.to_numpy(), "env dispatch")
    with pytest.raises(ValueError):
        L.groupby_aggregate(t, ["k"], {"v": "sum"}, impl="nope")
    with pytest.raises(ValueError):
        L.drop_duplicates(t, ["k"], impl="nope")


def test_counts_are_int32(rng):
    data = make_data("uniform", rng)
    t = Table.from_dict(data, capacity=ROWS)
    for impl in ("sort", "hash"):
        out = L.groupby_aggregate(t, ["k"], {"v": "count"}, impl=impl)
        assert out.columns["v_count"].dtype == np.int32, impl
    assert L.aggregate(t, "v", "count").dtype == np.int32


@pytest.mark.parametrize("world", [1, 2, 4])
def test_dist_groupby_conformance(world):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={world}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable,
         os.path.join(HERE, "dist", "groupby_conformance.py"), str(world)],
        env=env, capture_output=True, text=True, timeout=1800)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, \
        f"groupby conformance failed (world={world})"
    assert "GROUPBY CONFORMANCE PASSED" in proc.stdout
