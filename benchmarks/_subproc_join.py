"""Subprocess worker: distributed join at a given parallelism + backend.

Usage: XLA_FLAGS=...device_count=W python _subproc_join.py W rows impl
(``impl`` is the local join backend: sortmerge | hash).
Prints one JSON line:
{"world": W, "impl": impl, "seconds": s, "rows": N, "out_rows": M,
 "dropped": d}.
"""
import json
import sys
import time

import numpy as np


def main():
    world = int(sys.argv[1])
    rows = int(sys.argv[2])
    impl = sys.argv[3] if len(sys.argv) > 3 else "sortmerge"
    import jax
    from jax.sharding import Mesh
    from repro.core import dist_ops as D
    from repro.core.context import make_context

    dev = np.array(jax.devices()[:world])
    ctx = make_context(Mesh(dev, ("data",)))
    rng = np.random.default_rng(0)
    # paper Fig. 4: two relations, ~10% key uniqueness (high collision)
    nkeys = max(rows // 10, 1)
    left = {"k": rng.integers(0, nkeys, rows).astype(np.int32),
            "lv": rng.normal(size=rows).astype(np.float32)}
    right = {"k": rng.integers(0, nkeys, rows).astype(np.int32),
             "rv": rng.normal(size=rows).astype(np.float32)}
    gl = D.distribute_table(ctx, left)
    gr = D.distribute_table(ctx, right)
    # size every static capacity (shuffle slabs, join output, hash slabs)
    # exactly from the key distributions instead of blind overcommit
    plan = D.plan_dist_join_sizes([left["k"]], [right["k"]], world=world,
                                  local_impl=impl)
    pipe = D.DistributedPipeline(
        ctx, lambda c, a, b: D.dist_join(
            c, a, b, left_on=["k"],
            out_capacity=plan["out_capacity"],
            shuffle_sizes=plan["shuffle_sizes"],
            local_impl=impl,
            local_join_sizes=plan["local_join_sizes"]))
    out, dropped = pipe(gl, gr)             # compile + first run
    jax.block_until_ready(out.nvalid)
    ts = []
    for _ in range(3):
        t0 = time.perf_counter()
        out, dropped = pipe(gl, gr)
        jax.block_until_ready(out.nvalid)
        ts.append(time.perf_counter() - t0)
    n_out = int(np.sum(np.asarray(out.nvalid)))
    print(json.dumps({"world": world, "impl": impl,
                      "seconds": float(np.median(ts)),
                      "rows": rows, "out_rows": n_out,
                      "dropped": int(np.max(np.asarray(dropped)))}))


if __name__ == "__main__":
    main()
