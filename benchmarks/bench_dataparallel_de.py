"""Paper Figs. 13/14/15 — Multi-core data-parallel data engineering.

The paper scales the UNOMT preprocessing workload over cores (Fig. 13),
reports relative speed-up (Fig. 14) and multi-node scaling (Fig. 15).
Here: the distributed UNOMT pipeline at parallelism 1/2/4/8 in
subprocesses (forced host devices).
"""
from __future__ import annotations

from .common import Reporter, run_subprocess_bench

N_RESPONSE = 100_000


def run(fast: bool = False):
    rep = Reporter("fig13_15_dataparallel_de")
    n = N_RESPONSE // 10 if fast else N_RESPONSE
    t1 = None
    for world in (1, 2, 4, 8):
        res = run_subprocess_bench("_subproc_unomt.py", world, world, n)
        rep.add(f"hptmt_p{world}", "seconds", res["de_seconds"], rows=n,
                dropped=res["dropped"])
        if world == 1:
            t1 = res["de_seconds"]
        else:
            rep.add(f"hptmt_p{world}", "speedup_vs_p1",
                    t1 / res["de_seconds"])
    rep.save()
    return rep


if __name__ == "__main__":
    run()
