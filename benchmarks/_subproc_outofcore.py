"""Subprocess worker: out-of-core morsel-driven join + groupby at a given
parallelism.

Usage: XLA_FLAGS=...device_count=W python _subproc_outofcore.py W rows chunk

Fig4-shaped data at out-of-core scale: a ``rows``-row fact table with 10%
key uniqueness streamed in ``chunk``-row morsels against a resident
``rows/10``-row dimension build side (one row per key, so the join emits
exactly ``rows`` rows).  The timed run is the full streaming pass —
distribute every chunk, run it through the cached pipeline, collect the
output morsels — i.e. end-to-end out-of-core throughput including the
one-time compile (amortized over the chunk count, as in production).

Prints one JSON line:
{"world": W, "rows": N, "chunk_rows": C, "chunks": k,
 "join_seconds": s, "join_out_rows": M, "join_dropped": d,
 "groupby_seconds": s2, "groups": g, "groupby_dropped": d2}
"""
import json
import math
import sys
import time

import numpy as np


def main():
    world = int(sys.argv[1])
    rows = int(sys.argv[2])
    chunk = int(sys.argv[3])
    import jax
    from jax.sharding import Mesh
    from repro.core import morsel as M
    from repro.core.context import make_context

    dev = np.array(jax.devices()[:world])
    ctx = make_context(Mesh(dev, ("data",)))
    rng = np.random.default_rng(0)
    nkeys = max(rows // 10, 1)
    left = {"k": rng.integers(0, nkeys, rows).astype(np.int32),
            "lv": rng.normal(size=rows).astype(np.float32)}
    right = {"k": np.arange(nkeys, dtype=np.int32),
             "rv": rng.normal(size=nkeys).astype(np.float32)}
    probe = M.ChunkedTable(left, chunk)
    out_rows = 0

    def sink(part):
        nonlocal out_rows               # stream, never materialize
        out_rows += len(part["k"])

    t0 = time.perf_counter()
    _, dropped = M.chunked_dist_join(
        ctx, probe, right, left_on=["k"],
        build_capacity_per_shard=math.ceil(nkeys / world * 2),
        sink=sink)
    join_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    g, gdropped = M.chunked_dist_groupby(
        ctx, probe, ["k"], {"lv": ["sum", "count"]},
        group_capacity_per_shard=math.ceil(nkeys / world * 2))
    groupby_s = time.perf_counter() - t0

    print(json.dumps({
        "world": world, "rows": rows, "chunk_rows": chunk,
        "chunks": probe.num_chunks,
        "join_seconds": join_s, "join_out_rows": out_rows,
        "join_dropped": int(dropped),
        "groupby_seconds": groupby_s, "groups": len(g["k"]),
        "groupby_dropped": int(gdropped)}))


if __name__ == "__main__":
    main()
