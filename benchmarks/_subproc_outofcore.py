"""Subprocess worker: out-of-core morsel-driven join + groupby at a given
parallelism.

Usage: XLA_FLAGS=...device_count=W \
           python _subproc_outofcore.py W rows chunk [source]

Fig4-shaped data at out-of-core scale: a ``rows``-row fact table with 10%
key uniqueness streamed in ``chunk``-row morsels against a resident
``rows/10``-row dimension build side (one row per key, so the join emits
exactly ``rows`` rows).  The timed run is the full streaming pass —
distribute every chunk, run it through the cached pipeline, collect the
output morsels — i.e. end-to-end out-of-core throughput including the
one-time compile (amortized over the chunk count, as in production).

``source`` is ``ram`` (default) or ``memmap``: the memmap leg spills the
probe columns to disk files and streams them back as ``np.memmap``
views — the truly-larger-than-memory path, where each morsel's rows are
paged in from disk by the chunk slice itself (``ChunkedTable`` chunks
are slices, so nothing is materialized until distribution).

Prints one JSON line:
{"world": W, "rows": N, "chunk_rows": C, "chunks": k, "source": s,
 "join_seconds": s, "join_out_rows": M, "join_dropped": d,
 "groupby_seconds": s2, "groups": g, "groupby_dropped": d2}
"""
import json
import math
import os
import sys
import tempfile
import time

import numpy as np


def _to_memmap(cols: dict, tmpdir: str) -> dict:
    out = {}
    for name, v in cols.items():
        path = os.path.join(tmpdir, f"{name}.bin")
        mm = np.memmap(path, dtype=v.dtype, mode="w+", shape=v.shape)
        mm[:] = v
        mm.flush()
        out[name] = np.memmap(path, dtype=v.dtype, mode="r",
                              shape=v.shape)
    return out


def main():
    world = int(sys.argv[1])
    rows = int(sys.argv[2])
    chunk = int(sys.argv[3])
    source = sys.argv[4] if len(sys.argv) > 4 else "ram"
    import jax
    from jax.sharding import Mesh
    from repro.core import morsel as M
    from repro.core.context import make_context

    dev = np.array(jax.devices()[:world])
    ctx = make_context(Mesh(dev, ("data",)))
    rng = np.random.default_rng(0)
    nkeys = max(rows // 10, 1)
    left = {"k": rng.integers(0, nkeys, rows).astype(np.int32),
            "lv": rng.normal(size=rows).astype(np.float32)}
    right = {"k": np.arange(nkeys, dtype=np.int32),
             "rv": rng.normal(size=nkeys).astype(np.float32)}
    tmpdir = None
    if source == "memmap":
        tmpdir = tempfile.mkdtemp(prefix="outofcore_")
        left = _to_memmap(left, tmpdir)
    probe = M.ChunkedTable(left, chunk)
    out_rows = 0

    def sink(part):
        nonlocal out_rows               # stream, never materialize
        out_rows += len(part["k"])

    t0 = time.perf_counter()
    _, dropped = M.chunked_dist_join(
        ctx, probe, right, left_on=["k"],
        build_capacity_per_shard=math.ceil(nkeys / world * 2),
        sink=sink)
    join_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    g, gdropped = M.chunked_dist_groupby(
        ctx, probe, ["k"], {"lv": ["sum", "count"]},
        group_capacity_per_shard=math.ceil(nkeys / world * 2))
    groupby_s = time.perf_counter() - t0

    print(json.dumps({
        "world": world, "rows": rows, "chunk_rows": chunk,
        "chunks": probe.num_chunks, "source": source,
        "join_seconds": join_s, "join_out_rows": out_rows,
        "join_dropped": int(dropped),
        "groupby_seconds": groupby_s, "groups": len(g["k"]),
        "groupby_dropped": int(gdropped)}))
    if tmpdir is not None:
        for f in os.listdir(tmpdir):
            os.unlink(os.path.join(tmpdir, f))
        os.rmdir(tmpdir)


if __name__ == "__main__":
    main()
