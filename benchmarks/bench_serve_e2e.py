"""End-to-end serving soak: continuous-batching decode fused with
distributed feature joins.

The engine (``repro/serving``) is soaked with a bursty, Zipf-skewed
closed-loop load (see ``_subproc_serve.py``): the full ``run()`` pushes
1000+ requests through the bounded admission queue, the feature-store
shuffle/join path, slot prefill, and the continuous-batching decode loop
— asserting zero silent drops (every rejection counted and retried,
every completed request carries exactly its requested tokens and the
bit-correct joined feature row) — and records sustained tokens/s,
feature rows/s, and p50/p99 latency.  ``tokens_per_sec`` /
``rows_per_sec`` rows are *lower-bound* gated by ``run.py
--check-budgets`` (a throughput regression fails the gate the same way
a ``seconds`` regression does).
"""
from __future__ import annotations

from .common import Reporter, run_subprocess_bench

REQUESTS = 1200        # acceptance: soak >= 1000 requests
FAST_REQUESTS = 120
SLOTS = 4
PROMPT_CAP = 16
GEN_CAP = 8
QUEUE_CAP = 32


def run(fast: bool = False):
    rep = Reporter("serve_e2e")
    n = FAST_REQUESTS if fast else REQUESTS
    for world in (1, 2):
        res = run_subprocess_bench(
            "_subproc_serve.py", world, world, n, SLOTS, PROMPT_CAP,
            GEN_CAP, QUEUE_CAP, timeout=3600)
        assert res["completed"] == n, res
        cfg = f"soak_p{world}"
        rep.add(cfg, "seconds", res["seconds"], rows=n,
                slots=SLOTS, rejected=res["rejected"],
                decode_steps=res["decode_steps"],
                tokens=res["tokens_generated"],
                max_queue_depth=res["max_queue_depth"])
        rep.add(cfg, "tokens_per_sec", res["tokens_per_sec"], rows=n)
        rep.add(cfg, "rows_per_sec", res["rows_per_sec"], rows=n)
        rep.add(cfg, "p50_latency_s", res["p50_latency_s"], rows=n)
        rep.add(cfg, "p99_latency_s", res["p99_latency_s"], rows=n)
    rep.save()
    return rep


if __name__ == "__main__":
    run()
