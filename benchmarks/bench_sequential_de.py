"""Paper Fig. 12 — Sequential (single-core) data engineering.

The paper times the UNOMT drug-response preprocessing workload on Pandas,
PyCylon and Modin single-core.  Here: the full UNOMT operator pipeline
through our jitted table engine vs a straight numpy implementation of the
same pipeline (the "pandas" stand-in available in this container).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.table import Table
from repro.data.unomt import (drug_feature_cols, gen_unomt_tables, rna_cols,
                              unomt_local_pipeline)

from .common import Reporter, timeit

N_RESPONSE = 100_000     # paper uses 2.5M samples; scaled for container


def numpy_pipeline(raw) -> np.ndarray:
    resp, desc, fp, rna = (raw["response"], raw["descriptors"],
                           raw["fingerprints"], raw["rna"])
    t = {k: resp[k] for k in ("drug_id_raw", "cell_id", "concentration",
                              "response")}
    t["drug_id"] = t.pop("drug_id_raw") - 1_000_000
    keep = ~np.isnan(t["response"])
    t = {k: v[keep] for k, v in t.items()}
    c = t["concentration"]
    t["concentration"] = (c - c.mean()) / (c.std() + 1e-12)
    # drug = desc join fp on drug_id (both indexed 0..n-1 -> direct merge)
    order = np.argsort(desc["drug_id"], kind="stable")
    drug = {k: v[order] for k, v in desc.items()}
    fpo = np.argsort(fp["drug_id"], kind="stable")
    for k, v in fp.items():
        if k != "drug_id":
            drug[k] = v[fpo]
    # rna dedup (first occurrence) + scale
    _, first = np.unique(rna["cell_id"], return_index=True)
    rna_u = {k: v[np.sort(first)] for k, v in rna.items()}
    for k in rna_u:
        if k != "cell_id":
            v = rna_u[k]
            rna_u[k] = (v - v.mean()) / (v.std() + 1e-12)
    # isin filters
    keep = np.isin(t["drug_id"], drug["drug_id"]) & \
        np.isin(t["cell_id"], rna_u["cell_id"])
    t = {k: v[keep] for k, v in t.items()}
    # join drug features then rna features (gather by key index)
    drug_pos = np.searchsorted(drug["drug_id"], t["drug_id"])
    rna_sort = np.argsort(rna_u["cell_id"], kind="stable")
    rna_pos = rna_sort[np.searchsorted(rna_u["cell_id"][rna_sort],
                                       t["cell_id"])]
    feats = [t["concentration"]]
    for k in drug_feature_cols():
        feats.append(drug[k][drug_pos])
    for k in rna_cols():
        feats.append(rna_u[k][rna_pos])
    return np.stack(feats, 1)


def run(fast: bool = False):
    rep = Reporter("fig12_sequential_de")
    n = N_RESPONSE // 10 if fast else N_RESPONSE
    raw = gen_unomt_tables(n_response=n, n_drugs=512, n_cells=256, seed=0)

    t_np = timeit(lambda: numpy_pipeline(raw), warmup=1, iters=3)
    rep.add("numpy_pipeline", "seconds", t_np, rows=n)

    tbls = {k: Table.from_dict(v) for k, v in raw.items()}

    @jax.jit
    def jit_pipeline(resp, desc, fp, rna):
        out = unomt_local_pipeline(resp, desc, fp, rna,
                                   out_capacity=resp.capacity)
        return out.to_tensor(["concentration"] + drug_feature_cols()
                             + rna_cols())

    def run_ours():
        jax.block_until_ready(jit_pipeline(
            tbls["response"], tbls["descriptors"], tbls["fingerprints"],
            tbls["rna"]))

    t_ours = timeit(run_ours, warmup=1, iters=3)
    rep.add("hptmt_table_engine", "seconds", t_ours, rows=n)
    rep.add("hptmt_table_engine", "vs_numpy_ratio", t_ours / t_np)
    rep.save()
    return rep


if __name__ == "__main__":
    run()
