"""Local GroupBy/Aggregate backend sweep — sort vs bucketed hash.

GroupBy/Aggregate is the hot path of ``dist_groupby`` / ``dist_unique``
/ ``dist_standard_scale``; the sort backend pays a full lexicographic
tuple sort per call, the hash backend one bucketed accumulate pass whose
cost scales with the per-bucket slab area.  This sweep times both local
backends (jitted, all five aggregations) across key cardinalities at a
fixed row count against a numpy sort-reduce baseline, and records the
crossover into ``results/bench.json``.  Bucket slabs are sized per
cardinality (low cardinality needs few, deep buckets — the static-shape
contract), and both backends must report identical group counts.
"""
from __future__ import annotations

from functools import partial

import jax
import numpy as np

from .common import Reporter, timeit

ROWS = 1024
CARDS = (16, 128, 1024)
AGGS = {"v": ["sum", "count", "mean", "min", "max"]}


def hash_sizes(nkeys: int, rows: int) -> dict:
    """Slab sizing per cardinality: worst expected bucket load with >=2x
    headroom (capacities are worst-case *per bucket*)."""
    if nkeys <= 16:
        return {"num_buckets": 8, "bucket_capacity": rows}
    if nkeys <= 128:
        return {"num_buckets": 32, "bucket_capacity": max(64, rows // 4)}
    return {"num_buckets": 128, "bucket_capacity": max(32, rows // 32)}


def numpy_groupby_baseline(keys: np.ndarray, vals: np.ndarray) -> float:
    def run():
        order = np.argsort(keys, kind="stable")
        ks, vs = keys[order], vals[order]
        b = np.flatnonzero(np.r_[True, ks[1:] != ks[:-1]]) \
            if len(ks) else np.zeros(0, np.int64)
        sums = np.add.reduceat(vs, b) if len(b) else np.zeros(0)
        counts = np.diff(np.r_[b, len(ks)])
        return sums, counts

    return timeit(run, warmup=1, iters=3)


def run(fast: bool = False):
    from repro.core import local_ops as L
    from repro.core.table import Table

    rep = Reporter("groupby_local_backends")
    rows = ROWS // 4 if fast else ROWS
    rng = np.random.default_rng(0)
    for nkeys in CARDS:
        nkeys = min(nkeys, rows)
        keys = rng.integers(0, nkeys, rows).astype(np.int32)
        vals = rng.integers(-100, 100, rows).astype(np.float32)
        rep.add(f"numpy_k{nkeys}", "seconds",
                numpy_groupby_baseline(keys, vals), rows=rows)
        t = Table.from_dict({"k": keys, "v": vals})
        per_impl = {}
        for impl in ("sort", "hash"):
            kw = hash_sizes(nkeys, rows) if impl == "hash" else {}
            fn = jax.jit(partial(L.groupby_aggregate, by=["k"], aggs=AGGS,
                                 impl=impl, return_overflow=True, **kw))
            out, over = jax.block_until_ready(fn(t))
            assert int(over) == 0, (impl, nkeys)
            secs = timeit(lambda: jax.block_until_ready(fn(t)))
            per_impl[impl] = (secs, int(out.nvalid))
            rep.add(f"{impl}_k{nkeys}", "seconds", secs, rows=rows,
                    groups=int(out.nvalid))
        assert per_impl["sort"][1] == per_impl["hash"][1], \
            "backend group-count mismatch"
        rep.add(f"hash_k{nkeys}", "speedup_vs_sort",
                per_impl["sort"][0] / per_impl["hash"][0])
    rep.save()
    return rep


if __name__ == "__main__":
    run()
