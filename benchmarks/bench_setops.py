"""Local semi-join / set-operator backend sweep — sortmerge vs bucketed
hash membership.

isin/intersect/difference are the hot path of the UNOMT Fig.-11 filter
and of ``dist_isin``/``dist_intersect``/``dist_difference``; the
sortmerge backend pays a full lexicographic sort of the value set per
call, the hash backend one bucketed build+probe pass whose cost scales
with the slab area.  This sweep times isin, intersect and difference
under both backends (jitted) across key cardinalities at a fixed row
count against a ``np.isin`` baseline, and records the speedups into
``results/bench.json``.  Slabs are sized per cardinality (the
static-shape contract) and both backends must report identical surviving
row counts.
"""
from __future__ import annotations

from functools import partial

import jax
import numpy as np

from .common import Reporter, timeit

ROWS = 1024
CARDS = (16, 128, 1024)


def semi_sizes(nkeys: int, rows: int) -> dict:
    """Slab sizing per cardinality: worst expected bucket load with >=2x
    headroom (capacities are worst-case *per bucket*, build AND probe)."""
    if nkeys <= 16:
        return {"num_buckets": 8, "bucket_capacity": rows,
                "probe_capacity": rows}
    if nkeys <= 128:
        return {"num_buckets": 32, "bucket_capacity": max(64, rows // 4),
                "probe_capacity": max(64, rows // 4)}
    return {"num_buckets": 128, "bucket_capacity": max(32, rows // 8),
            "probe_capacity": max(32, rows // 8)}


def numpy_isin_baseline(keys: np.ndarray, vals: np.ndarray) -> float:
    return timeit(lambda: np.isin(keys, vals), warmup=1, iters=3)


def run(fast: bool = False):
    from repro.core import local_ops as L
    from repro.core.table import Table

    rep = Reporter("setops_local_backends")
    rows = ROWS // 4 if fast else ROWS
    rng = np.random.default_rng(0)
    for nkeys in CARDS:
        nkeys = min(nkeys, rows)
        ka = rng.integers(0, nkeys, rows).astype(np.int32)
        kb = rng.integers(nkeys // 2, nkeys + nkeys // 2,
                          rows // 2).astype(np.int32)
        rep.add(f"numpy_isin_k{nkeys}", "seconds",
                numpy_isin_baseline(ka, kb), rows=rows)
        a = Table.from_dict({"k": ka,
                             "v": np.arange(rows, dtype=np.float32)})
        b = Table.from_dict({"k": kb})
        for op, call in (
                ("isin", lambda t, v, **kw: L.isin(
                    t, "k", v, "k", return_overflow=True, **kw)),
                ("intersect", lambda t, v, **kw: L.intersect(
                    t, v, on=["k"], return_overflow=True, **kw)),
                ("difference", lambda t, v, **kw: L.difference(
                    t, v, on=["k"], return_overflow=True, **kw))):
            per_impl = {}
            for impl in ("sortmerge", "hash"):
                kw = semi_sizes(nkeys, rows) if impl == "hash" else {}
                fn = jax.jit(partial(call, impl=impl, **kw))
                out, over = jax.block_until_ready(fn(a, b))
                assert int(over) == 0, (op, impl, nkeys)
                count = int(np.asarray(out).sum()) if op == "isin" \
                    else int(out.nvalid)
                secs = timeit(lambda: jax.block_until_ready(fn(a, b)))
                per_impl[impl] = (secs, count)
                rep.add(f"{op}_{impl}_k{nkeys}", "seconds", secs,
                        rows=rows, kept=count)
            assert per_impl["sortmerge"][1] == per_impl["hash"][1], \
                f"{op} backend row-count mismatch"
            rep.add(f"{op}_hash_k{nkeys}", "speedup_vs_sortmerge",
                    per_impl["sortmerge"][0] / per_impl["hash"][0])
    rep.save()
    return rep


if __name__ == "__main__":
    run()
