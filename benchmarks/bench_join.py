"""Paper Fig. 4 — Distributed Join performance and scaling.

The paper joins two 200M-row relations with 10% key uniqueness at up to
128 processes and compares Cylon vs Dask/Modin.  Here, two sweeps against
a numpy sort-merge baseline as the single-core reference ("pandas"
stand-in; pandas is not installed in this container):

* the paper scaling sweep — our HPTMT distributed join (default
  sort-merge local backend) at parallelism 1/2/4/8 (forced host devices,
  one subprocess each so device counts don't leak);
* the local-backend sweep — sortmerge vs hash local join through the same
  distributed pipeline, at a reduced row count (the bucketed hash probe
  materializes per-bucket match slabs, which is sized for TPU VMEM tiles,
  not for this CPU-interpret container).
"""
from __future__ import annotations

import numpy as np

from .common import Reporter, run_subprocess_bench, timeit

ROWS = 200_000        # paper: 200M; scaled /1000 for CPU-only container
BACKEND_ROWS = 20_000  # sortmerge-vs-hash comparison sweep


def numpy_join_baseline(rows: int) -> float:
    rng = np.random.default_rng(0)
    nkeys = rows // 10
    lk = rng.integers(0, nkeys, rows).astype(np.int32)
    lv = rng.normal(size=rows).astype(np.float32)
    rk = rng.integers(0, nkeys, rows).astype(np.int32)
    rv = rng.normal(size=rows).astype(np.float32)

    def join():
        ls = np.argsort(lk, kind="stable")
        rs = np.argsort(rk, kind="stable")
        lks, rks = lk[ls], rk[rs]
        lo = np.searchsorted(rks, lks, "left")
        hi = np.searchsorted(rks, lks, "right")
        cnt = hi - lo
        out_l = np.repeat(ls, cnt)
        offs = np.repeat(np.cumsum(cnt) - cnt, cnt)
        within = np.arange(cnt.sum()) - offs
        out_r = rs[np.repeat(lo, cnt) + within]
        return lv[out_l] + rv[out_r]

    return timeit(join, warmup=1, iters=3)


def run(fast: bool = False):
    rep = Reporter("fig4_distributed_join")
    rows = ROWS // 4 if fast else ROWS
    base_s = numpy_join_baseline(rows)
    rep.add("numpy_1core", "seconds", base_s, rows=rows)
    t1 = None
    for world in (1, 2, 4, 8):
        res = run_subprocess_bench("_subproc_join.py", world, world, rows,
                                   "sortmerge")
        rep.add(f"hptmt_p{world}", "seconds", res["seconds"], rows=rows,
                out_rows=res["out_rows"], dropped=res["dropped"],
                vs_numpy=base_s / res["seconds"])
        if world == 1:
            t1 = res["seconds"]
        else:
            rep.add(f"hptmt_p{world}", "speedup_vs_p1",
                    t1 / res["seconds"])
    rep.save()

    # local-backend sweep: same pipeline, both local join backends
    repb = Reporter("join_local_backends")
    brows = BACKEND_ROWS // 4 if fast else BACKEND_ROWS
    bbase_s = numpy_join_baseline(brows)
    repb.add("numpy_1core", "seconds", bbase_s, rows=brows)
    for world in (1, 2, 4):
        per_impl = {}
        for impl in ("sortmerge", "hash"):
            res = run_subprocess_bench("_subproc_join.py", world, world,
                                       brows, impl)
            repb.add(f"{impl}_p{world}", "seconds", res["seconds"],
                     rows=brows, out_rows=res["out_rows"],
                     dropped=res["dropped"],
                     vs_numpy=bbase_s / res["seconds"])
            per_impl[impl] = res
        assert per_impl["sortmerge"]["out_rows"] == \
            per_impl["hash"]["out_rows"], "backend row-count mismatch"
        repb.add(f"hash_p{world}", "speedup_vs_sortmerge",
                 per_impl["sortmerge"]["seconds"]
                 / per_impl["hash"]["seconds"])
    repb.save()
    return rep


if __name__ == "__main__":
    run()
