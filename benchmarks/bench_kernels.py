"""Kernel micro-benchmarks: ref-path wall time on CPU + analytic TPU
roofline for the Pallas kernels (the container has no TPU; the kernels'
claimed VMEM tiling and per-byte/per-flop costs are reported against the
v5e constants used in §Roofline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hash_partition import partition_plan
from repro.kernels.mamba_scan.ref import selective_scan_ref
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS

from .common import Reporter, timeit


def run(fast: bool = False):
    rep = Reporter("kernel_micro")

    # -- hash partition: the shuffle/MoE dispatch hot spot -----------------
    n, parts = (1 << 16, 64) if fast else (1 << 20, 256)
    pid = jnp.asarray(np.random.default_rng(0)
                      .integers(0, parts, n).astype(np.int32))
    f = jax.jit(lambda p: partition_plan(p, parts, impl="ref"),
                static_argnames=())
    t = timeit(lambda: jax.block_until_ready(f(pid)))
    rep.add(f"hash_partition_n{n}_p{parts}", "cpu_ref_seconds", t)
    # analytic TPU: one-hot (tile,P) int32 ops; traffic = read pid + write
    # hist/ranks ~ 12 B/row
    rep.add(f"hash_partition_n{n}_p{parts}", "tpu_roofline_us",
            (12.0 * n) / HBM_BW * 1e6)

    # -- flash attention ---------------------------------------------------
    B, H, S, D = (1, 4, 1024, 64) if fast else (2, 8, 2048, 128)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, H, S, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, H, S, D), jnp.bfloat16)
    fa = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    t = timeit(lambda: jax.block_until_ready(fa(q, k, v)))
    rep.add(f"flash_attn_b{B}h{H}s{S}d{D}", "cpu_ref_seconds", t)
    flops = 4.0 * B * H * S * S * D * 0.5          # causal half
    rep.add(f"flash_attn_b{B}h{H}s{S}d{D}", "tpu_roofline_us",
            flops / PEAK_FLOPS * 1e6)

    # -- mamba selective scan ----------------------------------------------
    B2, S2, E, N = (1, 512, 512, 16) if fast else (2, 2048, 1024, 16)
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    x = jax.random.normal(ks[0], (B2, S2, E), jnp.float32)
    delta = jax.nn.softplus(jax.random.normal(ks[1], (B2, S2, E)))
    A = -jnp.exp(jax.random.normal(ks[2], (E, N)) * 0.5)
    Bm = jax.random.normal(ks[3], (B2, S2, N))
    Cm = jax.random.normal(ks[4], (B2, S2, N))
    Dp = jax.random.normal(ks[5], (E,))
    ss = jax.jit(lambda *a: selective_scan_ref(*a)[0])
    t = timeit(lambda: jax.block_until_ready(ss(x, delta, A, Bm, Cm, Dp)))
    rep.add(f"mamba_scan_b{B2}s{S2}e{E}", "cpu_ref_seconds", t)
    # memory-bound: read x/delta/B/C + write y
    traffic = (3 * B2 * S2 * E + 2 * B2 * S2 * N) * 4.0
    rep.add(f"mamba_scan_b{B2}s{S2}e{E}", "tpu_roofline_us",
            traffic / HBM_BW * 1e6)

    rep.save()
    return rep


if __name__ == "__main__":
    run()
