"""Collective-traffic attribution tool for the perf loop (§Perf).

    PYTHONPATH=src python -m benchmarks.attr_collectives \
        --arch qwen3-moe-235b-a22b --cell train_4k [--top 12] [--meta]

Lowers the cell on the single-pod mesh, walks the HLO with trip-count
multipliers, and prints the top collective ops by link bytes.
"""
import argparse
import os
import re
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", default="train_4k")
    ap.add_argument("--top", type=int, default=12)
    ap.add_argument("--meta", action="store_true")
    ap.add_argument("--overrides", default=None)
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax                                             # noqa: E402
    from repro.launch.dryrun import build_lowered          # noqa: E402
    from repro.launch.mesh import make_production_mesh     # noqa: E402
    from repro.configs import get_config                   # noqa: E402
    from repro.roofline import hlo_cost as H               # noqa: E402
    import dataclasses

    cfg = get_config(args.arch)
    if args.overrides:
        ov = {}
        for kv in args.overrides.split(","):
            k, v = kv.split("=")
            if v in ("True", "true"):
                v = True
            elif v in ("False", "false"):
                v = False
            else:
                try:
                    v = int(v)
                except ValueError:
                    pass
            ov[k] = v
        cfg = dataclasses.replace(
            cfg, train=dataclasses.replace(cfg.train, **ov))
    compiled = build_lowered(cfg, args.cell,
                             make_production_mesh()).compile()
    comps = H.parse_module(compiled.as_text())
    entry = next(n for n in comps if n.startswith("main"))

    rows = []

    def walk(comp, mult):
        for op in comp.ops:
            oc = op.opcode
            base = oc[:-6] if oc.endswith("-start") else oc
            if oc.endswith("-done"):
                continue
            if base in H._COLLECTIVES:
                b = H._shape_bytes(op.out_shapes)
                if oc.endswith("-start") and len(op.out_shapes) > 1:
                    b /= 2
                b *= H._wire_factor(op, comp, comps)
                g = H._group_size(op.line)
                rows.append((b * H._ring_factor(base, g) * mult, b, mult,
                             g, base, op.line))
            elif oc == "while":
                mb = H._BODY_RE.search(op.line)
                t = H._trip_count(op, comps)
                if mb and mb.group(1) in comps:
                    walk(comps[mb.group(1)], mult * t)

    walk(comps[entry], 1.0)
    rows.sort(reverse=True)
    tot = sum(r[0] for r in rows)
    print(f"total link bytes {tot:.4g} -> {tot / 50e9:.2f}s on ICI")
    for link, b, mult, g, kind, line in rows[:args.top]:
        print(f"{link:.3g} ({b:.3g} x{mult:.0f} g={g}) {kind}")
        if args.meta:
            m = re.search(r'op_name="([^"]+)', line)
            print("   meta:", (m.group(1) if m else "?")[:160])
        shapes = re.findall(r"\w+\[[\d,]*\]", line)[:8]
        print("   shapes:", shapes)


if __name__ == "__main__":
    main()
