"""Local OrderBy backend sweep — xla (lax.sort) vs multi-pass radix.

sort_values is the hot path of ``dist_sort`` (sample-sort) and of every
sort-based operator backend; the xla backend pays one stable
``lax.sort`` per call, the radix backend a fixed chain of counting-sort
digit passes (``kernels/radix_sort``) whose cost is linear in rows.
This sweep times both local backends (jitted, two-key sort) across key
cardinalities at a fixed row count against a numpy stable-sort baseline,
plus a ``dist_sort`` leg through a world-1 DistributedPipeline with each
local backend, and records the results into ``results/bench.json``.
Both backends must report bit-identical key columns (the conformance
contract) — asserted here on every config.
"""
from __future__ import annotations

from functools import partial

import jax
import numpy as np

from .common import Reporter, timeit

ROWS = 2048
CARDS = (16, 256, 2048)


def numpy_sort_baseline(keys: np.ndarray, vals: np.ndarray) -> float:
    def run():
        order = np.argsort(keys, kind="stable")
        return keys[order], vals[order]

    return timeit(run, warmup=1, iters=3)


def run(fast: bool = False):
    from repro.core import dist_ops as D, local_ops as L
    from repro.core.context import make_context
    from jax.sharding import Mesh

    rep = Reporter("sort_local_backends")
    rows = ROWS // 4 if fast else ROWS
    rng = np.random.default_rng(0)
    from repro.core.table import Table

    for nkeys in CARDS:
        nkeys = min(nkeys, rows)
        keys = rng.integers(-nkeys // 2, nkeys // 2, rows).astype(np.int32)
        vals = rng.integers(-100, 100, rows).astype(np.float32)
        rep.add(f"numpy_k{nkeys}", "seconds",
                numpy_sort_baseline(keys, vals), rows=rows)
        t = Table.from_dict({"k": keys, "v": vals})
        per_impl = {}
        for impl in ("xla", "radix"):
            fn = jax.jit(partial(L.sort_values, by=["k", "v"], impl=impl))
            out = jax.block_until_ready(fn(t))
            secs = timeit(lambda: jax.block_until_ready(fn(t)))
            per_impl[impl] = (secs, np.asarray(out.columns["k"]))
            rep.add(f"{impl}_k{nkeys}", "seconds", secs, rows=rows)
        np.testing.assert_array_equal(per_impl["xla"][1],
                                      per_impl["radix"][1],
                                      err_msg="backends diverged")
        rep.add(f"radix_k{nkeys}", "speedup_vs_xla",
                per_impl["xla"][0] / per_impl["radix"][0])

    # dist_sort leg (world 1 in-process; multi-device scaling lives in
    # tests/dist/sort_conformance.py, run under forced host devices)
    ctx = make_context(Mesh(np.array(jax.devices()[:1]), ("data",)))
    data = {"k": rng.integers(-1000, 1000, rows).astype(np.int32),
            "v": rng.normal(size=rows).astype(np.float32)}
    for impl in ("xla", "radix"):
        gt = D.distribute_table(ctx, data)
        pipe = D.DistributedPipeline(
            ctx, lambda c, a, impl=impl: D.dist_sort(c, a, ["k"],
                                                     local_impl=impl))
        out, dropped = jax.block_until_ready(pipe(gt))
        assert int(np.max(np.asarray(dropped))) == 0, impl
        secs = timeit(lambda: jax.block_until_ready(pipe(gt)))
        rep.add(f"dist_{impl}_w1", "seconds", secs, rows=rows)
    rep.save()
    return rep


if __name__ == "__main__":
    run()
