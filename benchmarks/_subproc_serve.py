"""Subprocess worker: continuous-batching serve soak with feature joins.

Usage: XLA_FLAGS=...device_count=W python _subproc_serve.py W requests \
           slots prompt_cap gen_cap queue_cap

Drives :class:`repro.serving.ServingEngine` (reduced lm100m, greedy
decode) with a *bursty, skewed* closed-loop load generator:

* arrivals come in bursts of random size with a random number of engine
  steps between bursts — the continuous-batching scheduler sees queue
  buildup, backpressure, and idle-slot stretches, not a smooth stream;
* feature keys are Zipf-skewed (a hot drug/cell dominates), exercising
  the skew-proof probe sizing of the feature-store shuffle/join;
* requests rejected by the bounded admission queue are *counted* and
  retried until admitted — at the end every request has completed, and
  the accounting identity ``submitted == completed + rejected`` is
  asserted along with zero feature-path drops (no silent loss anywhere).

Every completed request is checked: exactly ``gen_len`` tokens out and
its joined features bit-equal to the numpy gather reference.

Prints one JSON line with wall seconds, sustained tokens/s, feature
rows/s, and latency percentiles.
"""
import collections
import json
import sys
import time

import numpy as np


def main():
    world = int(sys.argv[1])
    n_requests = int(sys.argv[2])
    slots = int(sys.argv[3])
    prompt_cap = int(sys.argv[4])
    gen_cap = int(sys.argv[5])
    queue_cap = int(sys.argv[6])

    import jax
    from jax.sharding import Mesh
    from repro.configs import get_reduced
    from repro.core.context import make_context
    from repro.models import model as M
    from repro.serving import FeatureStore, Request, ServingEngine

    dev = np.array(jax.devices()[:world])
    ctx = make_context(Mesh(dev, ("data",)))
    cfg = get_reduced("lm100m")
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    n_drugs, n_cells, n_feat = 512, 256, 4
    drug_feat = rng.normal(size=(n_drugs, n_feat)).astype(np.float32)
    cell_feat = rng.normal(size=(n_cells, n_feat)).astype(np.float32)
    drug = {"drug_id": np.arange(n_drugs, dtype=np.int32),
            **{f"d{j}": drug_feat[:, j] for j in range(n_feat)}}
    rna = {"cell_id": np.arange(n_cells, dtype=np.int32),
           **{f"r{j}": cell_feat[:, j] for j in range(n_feat)}}
    cap = max(slots, 8)
    stores = {
        "drug_id": FeatureStore(ctx, "drug_id", drug, probe_capacity=cap,
                                chunk_rows=128),
        "cell_id": FeatureStore(ctx, "cell_id", rna, probe_capacity=cap,
                                chunk_rows=128),
    }
    eng = ServingEngine(cfg, params, slots=slots,
                        prompt_capacity=prompt_cap, gen_capacity=gen_cap,
                        queue_capacity=queue_cap, feature_stores=stores)

    # Zipf-skewed keys: a handful of hot drugs/cells dominate
    zipf = lambda n, size: ((rng.zipf(1.3, size) - 1) % n).astype(int)
    dids = zipf(n_drugs, n_requests)
    cids = zipf(n_cells, n_requests)
    pending = collections.deque(
        Request(req_id=i,
                prompt=rng.integers(0, cfg.vocab,
                                    rng.integers(1, prompt_cap + 1)
                                    ).astype(np.int32),
                gen_len=int(rng.integers(1, gen_cap + 1)),
                drug_id=int(dids[i]), cell_id=int(cids[i]))
        for i in range(n_requests))
    retry = collections.deque()
    done = []

    t0 = time.perf_counter()
    while pending or retry or eng.busy:
        burst = int(rng.integers(1, 2 * queue_cap))
        for _ in range(burst):
            src = retry if retry else pending
            if not src:
                break
            r = src.popleft()
            if not eng.submit(r):
                retry.append(r)           # counted; retried later
                break                     # backpressure: stop the burst
        for _ in range(int(rng.integers(1, 5))):
            done.extend(eng.step())
            if not eng.busy:
                break
    done.extend(eng.run_until_drained())
    wall = time.perf_counter() - t0

    m = eng.metrics
    # no silent drops anywhere: every submit is accounted for, every
    # request eventually completed, the feature path dropped nothing
    assert m.count("submitted") == m.count("completed") + \
        m.count("rejected") + m.count("feature_misses"), m.snapshot()
    assert m.count("feature_misses") == 0, m.snapshot()
    assert len(done) == n_requests, (len(done), n_requests)
    assert sorted(r.req_id for r in done) == list(range(n_requests))
    for s in stores.values():
        assert s.dropped == 0, "feature path dropped rows"
    for r in done:
        assert r.status == "done" and len(r.out_tokens) == r.gen_len, \
            (r.req_id, r.status)
        for j in range(n_feat):          # joined features are correct
            assert r.features[f"d{j}"] == drug_feat[r.drug_id, j], r.req_id
            assert r.features[f"r{j}"] == cell_feat[r.cell_id, j], r.req_id

    print(json.dumps({
        "world": world, "requests": n_requests, "slots": slots,
        "seconds": wall,
        "completed": m.count("completed"),
        "rejected": m.count("rejected"),
        "decode_steps": m.count("decode_steps"),
        "tokens_generated": m.count("tokens_generated"),
        "tokens_per_sec": m.count("tokens_generated") / wall,
        "feature_rows": m.count("feature_rows"),
        "rows_per_sec": m.count("feature_rows") / wall,
        "p50_latency_s": m.percentile("latency", 50),
        "p99_latency_s": m.percentile("latency", 99),
        "p50_ttft_s": m.percentile("ttft", 50),
        "max_queue_depth": m.gauges["queue_depth"]["max"],
    }))


if __name__ == "__main__":
    main()
