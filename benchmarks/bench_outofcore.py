"""Out-of-core morsel-driven execution at past-device-memory scale.

The fig4 bench joins 200 k-row relations that fit on device in one
piece; this leg streams a fig4-shaped (10% key uniqueness) fact table of
10 M+ rows — 50x the monolithic ceiling — through the chunk loops of
``core/morsel.py``: the probe side morselized against a resident build
side for the join, and per-chunk partial aggregates folded through the
groupby merge.  The device only ever holds one morsel plus resident
state; the recorded ``dropped`` must be zero (the aggregated
across-chunk counted-overflow contract) and ``out_rows`` must equal the
fact rows (every probe key hits exactly one build row).
"""
from __future__ import annotations

from .common import Reporter, run_subprocess_bench

ROWS = 10_000_000      # paper: 200M; 50x the monolithic fig4 leg
CHUNK = 1_000_000
FAST_ROWS = 400_000
FAST_CHUNK = 100_000


def run(fast: bool = False):
    rep = Reporter("outofcore_morsel")
    rows = FAST_ROWS if fast else ROWS
    chunk = FAST_CHUNK if fast else CHUNK
    for world in (2, 4):
        res = run_subprocess_bench("_subproc_outofcore.py", world, world,
                                   rows, chunk, timeout=3600)
        assert res["join_dropped"] == 0, res
        assert res["groupby_dropped"] == 0, res
        assert res["join_out_rows"] == rows, res
        rep.add(f"join_p{world}", "seconds", res["join_seconds"],
                rows=rows, chunk_rows=chunk, chunks=res["chunks"],
                out_rows=res["join_out_rows"],
                dropped=res["join_dropped"])
        rep.add(f"join_p{world}", "rows_per_sec",
                rows / res["join_seconds"], rows=rows)
        rep.add(f"groupby_p{world}", "seconds", res["groupby_seconds"],
                rows=rows, chunk_rows=chunk, out_rows=res["groups"],
                dropped=res["groupby_dropped"])
        rep.add(f"groupby_p{world}", "rows_per_sec",
                rows / res["groupby_seconds"], rows=rows)
    # disk-backed probe: same streaming pass with np.memmap columns —
    # morsels page in from disk as they are sliced (the
    # truly-larger-than-memory source)
    world = 2
    res = run_subprocess_bench("_subproc_outofcore.py", world, world,
                               rows, chunk, "memmap", timeout=3600)
    assert res["join_dropped"] == 0 and res["groupby_dropped"] == 0, res
    assert res["join_out_rows"] == rows, res
    rep.add(f"join_p{world}_memmap", "seconds", res["join_seconds"],
            rows=rows, chunk_rows=chunk, chunks=res["chunks"],
            out_rows=res["join_out_rows"], dropped=res["join_dropped"])
    rep.add(f"join_p{world}_memmap", "rows_per_sec",
            rows / res["join_seconds"], rows=rows)
    rep.add(f"groupby_p{world}_memmap", "seconds",
            res["groupby_seconds"], rows=rows, chunk_rows=chunk,
            out_rows=res["groups"], dropped=res["groupby_dropped"])
    rep.add(f"groupby_p{world}_memmap", "rows_per_sec",
            rows / res["groupby_seconds"], rows=rows)
    rep.save()
    return rep


if __name__ == "__main__":
    run()
