"""Out-of-core morsel-driven execution at past-device-memory scale.

The fig4 bench joins 200 k-row relations that fit on device in one
piece; this leg streams a fig4-shaped (10% key uniqueness) fact table of
10 M+ rows — 50x the monolithic ceiling — through the chunk loops of
``core/morsel.py``: the probe side morselized against a resident build
side for the join, and per-chunk partial aggregates folded through the
groupby merge.  The device only ever holds one morsel plus resident
state; the recorded ``dropped`` must be zero (the aggregated
across-chunk counted-overflow contract) and ``out_rows`` must equal the
fact rows (every probe key hits exactly one build row).
"""
from __future__ import annotations

import numpy as np

from .common import Reporter, run_subprocess_bench, timeit

ROWS = 10_000_000      # paper: 200M; 50x the monolithic fig4 leg
CHUNK = 1_000_000
FAST_ROWS = 400_000
FAST_CHUNK = 100_000


def numpy_outofcore_baseline(rows: int) -> tuple[float, float]:
    """Single-core numpy reference for the same fact-vs-dimension
    workload (whole-array, no chunking — the in-RAM best case the
    streaming engine is compared against): sort-merge style join via
    searchsorted on the sorted dimension keys, groupby via bincount."""
    rng = np.random.default_rng(0)
    nkeys = max(rows // 10, 1)
    lk = rng.integers(0, nkeys, rows).astype(np.int32)
    lv = rng.normal(size=rows).astype(np.float32)
    rk = np.arange(nkeys, dtype=np.int32)
    rv = rng.normal(size=nkeys).astype(np.float32)

    def join():
        order = np.argsort(rk, kind="stable")
        pos = np.searchsorted(rk[order], lk)
        return lv + rv[order[pos]]

    def groupby():
        return (np.bincount(lk, weights=lv, minlength=nkeys),
                np.bincount(lk, minlength=nkeys))

    return timeit(join, warmup=1, iters=3), \
        timeit(groupby, warmup=1, iters=3)


def run(fast: bool = False):
    rep = Reporter("outofcore_morsel")
    rows = FAST_ROWS if fast else ROWS
    chunk = FAST_CHUNK if fast else CHUNK
    join_base_s, groupby_base_s = numpy_outofcore_baseline(rows)
    rep.add("numpy_join_1core", "seconds", join_base_s, rows=rows)
    rep.add("numpy_groupby_1core", "seconds", groupby_base_s, rows=rows)
    for world in (2, 4):
        res = run_subprocess_bench("_subproc_outofcore.py", world, world,
                                   rows, chunk, timeout=3600)
        assert res["join_dropped"] == 0, res
        assert res["groupby_dropped"] == 0, res
        assert res["join_out_rows"] == rows, res
        rep.add(f"join_p{world}", "seconds", res["join_seconds"],
                rows=rows, chunk_rows=chunk, chunks=res["chunks"],
                out_rows=res["join_out_rows"],
                dropped=res["join_dropped"],
                vs_numpy=join_base_s / res["join_seconds"])
        rep.add(f"join_p{world}", "rows_per_sec",
                rows / res["join_seconds"], rows=rows)
        rep.add(f"groupby_p{world}", "seconds", res["groupby_seconds"],
                rows=rows, chunk_rows=chunk, out_rows=res["groups"],
                dropped=res["groupby_dropped"],
                vs_numpy=groupby_base_s / res["groupby_seconds"])
        rep.add(f"groupby_p{world}", "rows_per_sec",
                rows / res["groupby_seconds"], rows=rows)
    # disk-backed probe: same streaming pass with np.memmap columns —
    # morsels page in from disk as they are sliced (the
    # truly-larger-than-memory source)
    world = 2
    res = run_subprocess_bench("_subproc_outofcore.py", world, world,
                               rows, chunk, "memmap", timeout=3600)
    assert res["join_dropped"] == 0 and res["groupby_dropped"] == 0, res
    assert res["join_out_rows"] == rows, res
    rep.add(f"join_p{world}_memmap", "seconds", res["join_seconds"],
            rows=rows, chunk_rows=chunk, chunks=res["chunks"],
            out_rows=res["join_out_rows"], dropped=res["join_dropped"],
            vs_numpy=join_base_s / res["join_seconds"])
    rep.add(f"join_p{world}_memmap", "rows_per_sec",
            rows / res["join_seconds"], rows=rows)
    rep.add(f"groupby_p{world}_memmap", "seconds",
            res["groupby_seconds"], rows=rows, chunk_rows=chunk,
            out_rows=res["groups"], dropped=res["groupby_dropped"],
            vs_numpy=groupby_base_s / res["groupby_seconds"])
    rep.add(f"groupby_p{world}_memmap", "rows_per_sec",
            rows / res["groupby_seconds"], rows=rows)
    rep.save()
    return rep


if __name__ == "__main__":
    run()
