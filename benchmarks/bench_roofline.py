"""Roofline summary table (assignment §Roofline deliverable g).

Reads results/dryrun.json (written by launch/dryrun.py against the
16x16 / 2x16x16 production meshes) and prints the per-(arch×cell) terms.
No new compilation happens here — the dry-run is the profile source.
"""
from __future__ import annotations

import json
import os

from .common import REPO, Reporter


def _load(path, tag):
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        res = json.load(f)
    return {k[len(tag) + 1:]: v for k, v in res.items()
            if k.startswith(tag + "/") and v.get("ok")}


def run(fast: bool = False):
    rep = Reporter("roofline_table")
    base = _load(os.path.join(REPO, "results", "dryrun.json"), "baseline")
    opt = _load(os.path.join(REPO, "results", "dryrun_optimized.json"),
                "optimized")
    if not base:
        print("results/dryrun.json missing — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun` first")
        return rep
    print(f"{'cell':<52} {'bound':<11} {'compute_s':>10} {'memory_s':>10} "
          f"{'coll_s':>10} {'step_s':>10} {'MFU%':>7} {'opt_step':>9} "
          f"{'gain':>6}")
    for name, rec in sorted(base.items()):
        o = opt.get(name)
        ostep = f"{o['step_s']:>9.3f}" if o else "        -"
        gain = f"{rec['step_s'] / o['step_s']:>5.2f}x" if o \
            and o["step_s"] else "     -"
        print(f"{name:<52} {rec['bound']:<11} {rec['compute_s']:>10.4f} "
              f"{rec['memory_s']:>10.4f} {rec['collective_s']:>10.4f} "
              f"{rec['step_s']:>10.4f} {100 * rec['mfu']:>6.1f}% "
              f"{ostep} {gain}")
        rep.add(name, "step_s", rec["step_s"], bound=rec["bound"],
                mfu=rec["mfu"],
                **({"opt_step_s": o["step_s"], "opt_mfu": o["mfu"]}
                   if o else {}))
    rep.save()
    return rep


if __name__ == "__main__":
    run()
