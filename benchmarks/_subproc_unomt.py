"""Subprocess worker: distributed UNOMT data-engineering pipeline (paper
Figs. 13-15) and optional DDP training stage (Fig. 16).

Usage: python _subproc_unomt.py WORLD N_RESPONSE [train]
Prints one JSON line with timing.
"""
import json
import sys
import time

import numpy as np


def main():
    world = int(sys.argv[1])
    n = int(sys.argv[2])
    do_train = len(sys.argv) > 3 and sys.argv[3] == "train"
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.core import dist_ops as D
    from repro.core.context import make_context
    from repro.data.unomt import (feature_label_arrays, gen_unomt_tables,
                                  unomt_dist_pipeline)
    from repro.models import unomt_net
    from repro.optim import adamw

    dev = np.array(jax.devices()[:world])
    ctx = make_context(Mesh(dev, ("data",)))
    raw = gen_unomt_tables(n_response=n, n_drugs=512, n_cells=256, seed=0)
    caps = {k: max((len(next(iter(v.values()))) // world) * 2, 8)
            for k, v in raw.items()}
    gt = {k: D.distribute_table(ctx, v, capacity_per_shard=caps[k])
          for k, v in raw.items()}
    pipe = D.DistributedPipeline(
        ctx, lambda c, r, de, fp, rn: unomt_dist_pipeline(
            c, r, de, fp, rn, overcommit=3.0))

    def run_de():
        out, dropped = pipe(gt["response"], gt["descriptors"],
                            gt["fingerprints"], gt["rna"])
        jax.block_until_ready(out.nvalid)
        return out, dropped

    out, dropped = run_de()                      # compile
    ts = []
    for _ in range(3):
        t0 = time.perf_counter()
        out, dropped = run_de()
        ts.append(time.perf_counter() - t0)
    result = {"world": world, "de_seconds": float(np.median(ts)),
              "rows": n, "dropped": int(np.max(np.asarray(dropped)))}

    if do_train:
        # stage 3+4: features -> DDP train steps on the same mesh
        from repro.runtime.ddp import make_ddp_train_step
        from repro.optim import compression
        X_parts, y_parts, m_parts = [], [], []
        # table is row-sharded; to_tensor per shard via one more pipeline
        feat_pipe = D.DistributedPipeline(
            ctx, lambda c, t: feature_label_arrays(t))
        X, y, mask = feat_pipe(out)
        cfg = unomt_net.UnomtNetConfig(n_features=17, d_hidden=256,
                                       n_res_blocks=2, n_dense_tail=1,
                                       dropout=0.0)
        params = unomt_net.init(jax.random.PRNGKey(0), cfg)
        opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0)

        def loss_fn(p, batch):
            return unomt_net.mse_loss(p, cfg, batch)

        step = make_ddp_train_step(loss_fn, opt_cfg, ctx)
        opt = adamw.init(params, opt_cfg)
        res = compression.init_residuals(params)
        X = X.reshape(-1, X.shape[-1])
        y = y.reshape(-1)
        mask = mask.reshape(-1)
        batch = {"x": X, "y": y, "mask": mask}
        params, opt, res, _ = step(params, opt, res, batch)  # compile
        jax.block_until_ready(params)
        t0 = time.perf_counter()
        n_steps = 4
        for _ in range(n_steps):
            params, opt, res, metrics = step(params, opt, res, batch)
        jax.block_until_ready(params)
        result["train_seconds_per_step"] = (time.perf_counter() - t0) \
            / n_steps
        result["final_loss"] = float(np.asarray(metrics["loss"]))
    print(json.dumps(result))


if __name__ == "__main__":
    main()
