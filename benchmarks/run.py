"""Benchmark driver: one bench per paper table/figure + kernels + roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Prints ``bench,config,metric,value`` CSV rows and writes
results/bench.json.  Figure map:

    fig4   distributed join scaling            (paper Fig. 4)
    groupby  local groupby backend sweep       (sort vs bucketed hash)
    sort   local OrderBy backend sweep         (xla vs multi-pass radix)
    setops local semi-join backend sweep       (sortmerge vs hash probe)
    fig12  sequential data engineering         (paper Fig. 12)
    fig13  data-parallel data engineering      (paper Figs. 13-15)
    fig16  DDP deep learning on CPU            (paper Figs. 16/17)
    kernels  Pallas kernel micro-benchmarks
    roofline per-(arch×cell×mesh) roofline table (assignment §Roofline)
"""
from __future__ import annotations

import argparse

from . import (bench_dataparallel_de, bench_ddp_train, bench_groupby,
               bench_join, bench_kernels, bench_roofline,
               bench_sequential_de, bench_setops, bench_sort)

BENCHES = {
    "fig4": bench_join.run,
    "groupby": bench_groupby.run,
    "sort": bench_sort.run,
    "setops": bench_setops.run,
    "fig12": bench_sequential_de.run,
    "fig13": bench_dataparallel_de.run,
    "fig16": bench_ddp_train.run,
    "kernels": bench_kernels.run,
    "roofline": bench_roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI smoke)")
    ap.add_argument("--only", default=None, choices=sorted(BENCHES))
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    print("bench,config,metric,value")
    for name in names:
        print(f"# --- {name} ---", flush=True)
        BENCHES[name](fast=args.fast)


if __name__ == "__main__":
    main()
