"""Benchmark driver: one bench per paper table/figure + kernels + roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAMES]
                                            [--check-budgets]

Prints ``bench,config,metric,value`` CSV rows and writes
results/bench.json.  ``--only`` takes a comma-separated subset.  Figure
map:

    fig4   distributed join scaling            (paper Fig. 4)
    groupby  local groupby backend sweep       (sort vs bucketed hash)
    sort   local OrderBy backend sweep         (xla vs multi-pass radix)
    setops local semi-join backend sweep       (sortmerge vs hash probe)
    outofcore  morsel-driven join/groupby past device memory (10M+ rows)
    fig12  sequential data engineering         (paper Fig. 12)
    fig13  data-parallel data engineering      (paper Figs. 13-15)
    fig16  DDP deep learning on CPU            (paper Figs. 16/17)
    kernels  Pallas kernel micro-benchmarks
    roofline per-(arch×cell×mesh) roofline table (assignment §Roofline)
    serve  continuous-batching serve soak fused with feature joins

Perf-regression gate: ``--check-budgets`` snapshots the committed
``results/bench.json`` timings as per-row budgets *before* running,
re-runs the selected benches, and fails (exit 1) if any ``seconds`` row
regresses past ``--budget-factor`` (default 1.5x) its budget, or any
*throughput* row (``tokens_per_sec`` / ``rows_per_sec`` — lower is
worse) falls below its budget divided by the factor.  Rows are matched
by (bench, config, metric, rows), so a ``--fast`` gate run only
compares against committed fast-size baselines.
"""
from __future__ import annotations

import argparse
import sys

from . import (bench_dataparallel_de, bench_ddp_train, bench_groupby,
               bench_join, bench_kernels, bench_outofcore, bench_roofline,
               bench_sequential_de, bench_serve_e2e, bench_setops,
               bench_sort)
from .common import load_results, row_key

BENCHES = {
    "fig4": bench_join.run,
    "groupby": bench_groupby.run,
    "sort": bench_sort.run,
    "setops": bench_setops.run,
    "outofcore": bench_outofcore.run,
    "fig12": bench_sequential_de.run,
    "fig13": bench_dataparallel_de.run,
    "fig16": bench_ddp_train.run,
    "kernels": bench_kernels.run,
    "roofline": bench_roofline.run,
    "serve": bench_serve_e2e.run,
}

# metrics where lower is WORSE: gated as a lower bound (value must stay
# above budget / factor), unlike ``seconds`` which gates as an upper
# bound
THROUGHPUT_METRICS = ("tokens_per_sec", "rows_per_sec")


def check_budgets(budgets: dict, factor: float) -> list[str]:
    """Compare the saved ``seconds`` (upper-bound) and throughput
    (lower-bound) rows against the snapshotted budgets; rows a bench
    didn't re-run compare equal and pass trivially.  Returns the
    regression report lines."""
    failures = []
    checked = 0
    for r in load_results():
        metric = r.get("metric")
        if metric != "seconds" and metric not in THROUGHPUT_METRICS:
            continue
        budget = budgets.get(row_key(r))
        if budget is None or budget <= 0:
            continue                      # new row: no budget yet
        checked += 1
        if metric == "seconds":
            if r["value"] > factor * budget:
                failures.append(
                    f"  {r['bench']}/{r['config']} (rows={r.get('rows')}): "
                    f"{r['value']:.3f}s vs budget {budget:.3f}s "
                    f"({r['value'] / budget:.2f}x > {factor}x)")
        elif r["value"] < budget / factor:
            failures.append(
                f"  {r['bench']}/{r['config']} (rows={r.get('rows')}): "
                f"{metric} {r['value']:.1f} vs budget {budget:.1f} "
                f"({budget / max(r['value'], 1e-9):.2f}x below, "
                f"> {factor}x allowed)")
    print(f"# budget check: {checked} rows checked, "
          f"{len(failures)} regressions", flush=True)
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI smoke)")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names "
                         f"(choices: {', '.join(sorted(BENCHES))})")
    ap.add_argument("--check-budgets", action="store_true",
                    help="fail (exit 1) if a re-run 'seconds' row "
                         "regresses past --budget-factor x its committed "
                         "results/bench.json value")
    ap.add_argument("--budget-factor", type=float, default=1.5)
    args = ap.parse_args()
    if args.only:
        names = args.only.split(",")
        unknown = [n for n in names if n not in BENCHES]
        if unknown:
            ap.error(f"unknown bench(es) {unknown}; "
                     f"choices: {', '.join(sorted(BENCHES))}")
    else:
        names = list(BENCHES)
    budgets = {}
    if args.check_budgets:              # snapshot before benches overwrite
        budgets = {row_key(r): r["value"] for r in load_results()
                   if r.get("metric") == "seconds"
                   or r.get("metric") in THROUGHPUT_METRICS}
    print("bench,config,metric,value")
    for name in names:
        print(f"# --- {name} ---", flush=True)
        BENCHES[name](fast=args.fast)
    if args.check_budgets:
        failures = check_budgets(budgets, args.budget_factor)
        if failures:
            print("PERF BUDGET EXCEEDED:", flush=True)
            print("\n".join(failures), flush=True)
            sys.exit(1)
        print("# budget check passed", flush=True)


if __name__ == "__main__":
    main()
