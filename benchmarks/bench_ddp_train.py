"""Paper Fig. 16 — Distributed data-parallel deep learning (CPU).

The paper trains the UNOMT drug-response network with PyTorch-DDP over
MPI on CPUs.  Here: the same network through our BSP shard_map DDP step
(runtime.ddp) at parallelism 1/2/4/8, data-engineering stage included
(single source, single runtime — the paper's headline claim).
"""
from __future__ import annotations

from .common import Reporter, run_subprocess_bench

N_RESPONSE = 8_000


def run(fast: bool = False):
    rep = Reporter("fig16_ddp_train_cpu")
    n = N_RESPONSE // 10 if fast else N_RESPONSE
    t1 = None
    for world in (1, 2, 4, 8):
        res = run_subprocess_bench("_subproc_unomt.py", world, world, n,
                                   "train", timeout=1200)
        rep.add(f"hptmt_p{world}", "train_s_per_step",
                res["train_seconds_per_step"], rows=n,
                final_loss=res["final_loss"])
        if world == 1:
            t1 = res["train_seconds_per_step"]
        else:
            rep.add(f"hptmt_p{world}", "speedup_vs_p1",
                    t1 / res["train_seconds_per_step"])
    rep.save()
    return rep


if __name__ == "__main__":
    run()
