"""Benchmark utilities: timing, CSV records, subprocess multi-device runs."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Callable

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(REPO, "results")


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (after warmup compiles)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


class Reporter:
    """Collects (bench, config, metric, value) rows; prints CSV; saves."""

    def __init__(self, name: str):
        self.name = name
        self.rows: list[dict] = []

    def add(self, config: str, metric: str, value, **extra):
        row = {"bench": self.name, "config": config, "metric": metric,
               "value": float(value), **extra}
        self.rows.append(row)
        print(f"{self.name},{config},{metric},{value:.6g}", flush=True)

    def save(self):
        os.makedirs(RESULTS, exist_ok=True)
        path = os.path.join(RESULTS, "bench.json")
        existing = []
        if os.path.exists(path):
            with open(path) as f:
                existing = json.load(f)
        # replace per (bench, config, metric, rows) row — not the whole
        # bench — so a --fast run refreshes its own (smaller-``rows``)
        # rows without wiping the full-size baselines the perf gate
        # compares against (and vice versa)
        fresh = {row_key(r) for r in self.rows}
        existing = [r for r in existing if row_key(r) not in fresh]
        with open(path, "w") as f:
            json.dump(existing + self.rows, f, indent=1)


def row_key(r: dict) -> tuple:
    """Identity of a bench.json row: same bench/config/metric at the same
    problem size."""
    return (r.get("bench"), r.get("config"), r.get("metric"),
            r.get("rows"))


def load_results() -> list[dict]:
    path = os.path.join(RESULTS, "bench.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def run_subprocess_bench(script: str, n_devices: int, *args,
                         timeout: int = 900) -> dict:
    """Run a bench script with N forced host devices; parse last JSON line."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", script),
         *map(str, args)],
        env=env, capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"{script} failed:\n{proc.stderr[-2000:]}")
    for line in reversed(proc.stdout.strip().splitlines()):
        if line.startswith("{"):
            return json.loads(line)
    raise RuntimeError(f"no JSON result line in {script} output")
