"""Serving metrics: counters, gauges, and latency percentiles.

The engine's observability contract (see ``serving/README.md``): every
stage of the serving pipeline reports into one :class:`ServingMetrics`
registry so the millions-of-users story is *measurable* —

* **counters** (monotonic): ``submitted``, ``rejected`` (admission-queue
  overflow — the counted-rejection contract: a request is never silently
  dropped), ``admitted``, ``completed``, ``feature_misses`` (admitted but
  no feature row — terminal, counted), ``prefills``, ``decode_steps``,
  ``tokens_generated``, ``feature_rows`` (feature-table rows joined onto
  requests), ``feature_dropped`` (rows lost in the feature-fetch
  shuffle/join slabs — must stay 0 when sized right);
* **gauges** (last + max): ``queue_depth``, ``slot_occupancy``;
* **series** (observations in seconds): ``latency`` (submit -> done),
  ``ttft`` (submit -> first token), ``queue_wait`` (submit -> admit) —
  summarized as count/mean/p50/p99/max.

Percentiles use the nearest-rank method over everything observed (the
soak benches run minutes, not days — no reservoir needed).
"""
from __future__ import annotations

import collections

import numpy as np


class ServingMetrics:
    """In-process metrics registry for one engine instance."""

    def __init__(self):
        self.counters: dict[str, int] = collections.defaultdict(int)
        self.gauges: dict[str, dict[str, float]] = {}
        self.series: dict[str, list[float]] = collections.defaultdict(list)

    # ------------------------------------------------------------- recording
    def inc(self, name: str, n: int = 1) -> None:
        self.counters[name] += int(n)

    def gauge(self, name: str, value: float) -> None:
        g = self.gauges.setdefault(name, {"last": 0.0, "max": 0.0})
        g["last"] = float(value)
        g["max"] = max(g["max"], float(value))

    def observe(self, name: str, value: float) -> None:
        self.series[name].append(float(value))

    # --------------------------------------------------------------- reading
    def count(self, name: str) -> int:
        return self.counters.get(name, 0)

    def percentile(self, name: str, p: float) -> float:
        xs = self.series.get(name)
        if not xs:
            return float("nan")
        return float(np.percentile(np.asarray(xs), p,
                                   method="closest_observation"))

    def summary(self, name: str) -> dict[str, float]:
        xs = self.series.get(name, [])
        if not xs:
            return {"count": 0}
        a = np.asarray(xs)
        return {"count": int(a.size), "mean": float(a.mean()),
                "p50": self.percentile(name, 50),
                "p99": self.percentile(name, 99), "max": float(a.max())}

    def snapshot(self) -> dict:
        """The full metrics schema as one JSON-friendly dict."""
        return {
            "counters": dict(self.counters),
            "gauges": {k: dict(v) for k, v in self.gauges.items()},
            "latency": {k: self.summary(k) for k in self.series},
        }
