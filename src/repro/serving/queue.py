"""Bounded admission queue with counted rejections (backpressure stage).

The serving engine's front door follows the same contract as the table
kernels' static-shape slabs: a *bounded* buffer whose overflow is
**counted, never silent**.  ``offer`` on a full queue refuses the request
and increments the ``rejected`` counter — the caller learns immediately
(backpressure) and the soak benches can assert the accounting identity
``submitted == completed + rejected + feature_misses`` end to end.
"""
from __future__ import annotations

import collections
from typing import Optional

from .metrics import ServingMetrics


class AdmissionQueue:
    """FIFO queue with a hard capacity and counted rejections."""

    def __init__(self, capacity: int,
                 metrics: Optional[ServingMetrics] = None):
        if capacity <= 0:
            raise ValueError(f"queue capacity must be positive, got "
                             f"{capacity}")
        self.capacity = int(capacity)
        self.metrics = metrics if metrics is not None else ServingMetrics()
        self._items: collections.deque = collections.deque()

    def __len__(self) -> int:
        return len(self._items)

    @property
    def depth(self) -> int:
        return len(self._items)

    def offer(self, item) -> bool:
        """Admit ``item`` if there is room.  Returns False (and counts the
        rejection) when the queue is at capacity — never drops silently."""
        self.metrics.inc("submitted")
        if len(self._items) >= self.capacity:
            self.metrics.inc("rejected")
            self.metrics.gauge("queue_depth", len(self._items))
            return False
        self._items.append(item)
        self.metrics.gauge("queue_depth", len(self._items))
        return True

    def pop(self):
        """Dequeue the oldest item (None when empty)."""
        if not self._items:
            return None
        item = self._items.popleft()
        self.metrics.gauge("queue_depth", len(self._items))
        return item
