"""Fixed-shape slot micro-batching for continuous-batching decode.

The decode step is one cached jitted program over a *fixed* batch of
``n_slots`` sequences — arrivals of any cadence are mapped onto the
static batch shape, never onto a new trace.  :class:`SlotBatch` owns the
host-side per-slot state (which request occupies which slot, each slot's
cache length and current token) and hands the engine the dense
``(B, 1)`` token and ``(B,)`` cache-length arrays every step.

Continuous batching: when a sequence finishes, its slot is *released and
refilled immediately* from the admission queue (``free()`` ->
``occupy()``) while the other slots keep decoding — the batch never
drains to a barrier.  Idle slots still ride through the decode step
(fixed shape); their outputs are ignored and their cache is overwritten
wholesale at the next refill.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np


@dataclasses.dataclass
class _SlotState:
    request: Any                 # opaque engine request object
    gen_target: int              # tokens to generate before completion
    gen_count: int               # tokens generated so far (incl. prefill's)


class SlotBatch:
    """Host-side slot table: fixed ``n_slots`` rows of decode state."""

    def __init__(self, n_slots: int):
        if n_slots <= 0:
            raise ValueError(f"n_slots must be positive, got {n_slots}")
        self.n_slots = int(n_slots)
        self._slots: list[Optional[_SlotState]] = [None] * n_slots
        self.cache_lens = np.zeros(n_slots, np.int32)
        self.tokens = np.zeros((n_slots, 1), np.int32)

    # -------------------------------------------------------------- queries
    def free(self) -> list[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def active(self) -> list[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    @property
    def occupancy(self) -> int:
        return self.n_slots - len(self.free())

    def request_at(self, slot: int):
        s = self._slots[slot]
        return None if s is None else s.request

    # ------------------------------------------------------------ lifecycle
    def occupy(self, slot: int, request, *, first_token: int,
               prompt_len: int, gen_target: int) -> None:
        """Fill a freed slot with a freshly prefilled sequence: the prompt
        occupies cache positions ``[0, prompt_len)`` and ``first_token``
        (prefill's argmax) is the next token to decode at position
        ``prompt_len``."""
        if self._slots[slot] is not None:
            raise ValueError(f"slot {slot} is occupied")
        self._slots[slot] = _SlotState(request=request,
                                       gen_target=int(gen_target),
                                       gen_count=1)
        self.cache_lens[slot] = int(prompt_len)
        self.tokens[slot, 0] = int(first_token)

    def release(self, slot: int):
        """Free a slot; returns the request that occupied it."""
        s = self._slots[slot]
        if s is None:
            raise ValueError(f"slot {slot} is already free")
        self._slots[slot] = None
        return s.request

    def advance(self, next_tokens: np.ndarray,
                on_token=None) -> list[int]:
        """Fold one decode step's ``(B, 1)`` next-token array into the slot
        state: every *active* slot consumed its current token (written at
        ``cache_lens[slot]``) and produced the next one.  Returns the slots
        whose sequences just reached their generation target (caller
        releases and refills them — the continuous-batching step).

        ``on_token(slot, request, token)`` observes each active slot's
        newly decoded token."""
        finished = []
        for slot in self.active():
            s = self._slots[slot]
            tok = int(next_tokens[slot, 0])
            self.cache_lens[slot] += 1
            self.tokens[slot, 0] = tok
            s.gen_count += 1
            if on_token is not None:
                on_token(slot, s.request, tok)
            if s.gen_count >= s.gen_target:
                finished.append(slot)
        return finished
