"""Continuous-batching serving engine fused with distributed feature
joins (see ``engine.py`` for the stage-by-stage story and ``README.md``
for the metrics schema and counted-rejection contract)."""
from .batcher import SlotBatch
from .engine import FeatureStore, Request, ServingEngine
from .metrics import ServingMetrics
from .queue import AdmissionQueue

__all__ = ["AdmissionQueue", "FeatureStore", "Request", "ServingEngine",
           "ServingMetrics", "SlotBatch"]
