"""Continuous-batching serving engine fused with distributed feature joins.

The paper's thesis is deep learning and data engineering composed in one
efficient program (the UNOMT end-to-end application, Fig. 11).  This
module turns the repo's two halves — ``launch/serve.py``'s batched
prefill/decode with a static KV cache, and ``core/dist_ops.py``'s
distributed table operators — into one continuously *serving* system:

admission -> feature fetch -> slot prefill -> continuous-batching decode

* **Admission** (:class:`~repro.serving.queue.AdmissionQueue`): bounded,
  rejections counted — backpressure, never silent drops (the same
  counted-overflow contract as the table kernels).
* **Feature fetch** (:class:`FeatureStore`): each request's drug/RNA keys
  resolve against device-resident feature tables through the engine's own
  distributed operators — the resident side is hash-shuffled once at
  ingest (streamed from host in morsels, ``np.memmap``-backed sources
  included, so the feature tables may exceed device memory), and every
  micro-batch of keys runs shuffle + local join through one cached
  :class:`~repro.core.dist_ops.DistributedPipeline` — i.e. ``dist_join``
  with the build-side shuffle hoisted out of the request path.
* **Slot prefill** (``models.model.make_slot_prefill``): prompts are
  right-padded to one fixed shape, so every request — any prompt length —
  re-enters a single jitted prefill; the resulting KV cache is scattered
  into the running batch cache at the freed slot
  (``models.model.write_cache_slot``).
* **Decode** (``models.model.make_serve_step`` with per-slot cache
  lengths): ONE cached jitted ``serve_step`` with donated cache buffers
  drives the whole fixed-shape batch; as sequences finish, their slots
  are refilled from the queue immediately (continuous batching — the
  batch never drains to a barrier).

Every stage reports into :class:`~repro.serving.metrics.ServingMetrics`
(queue depth, rejects, slot occupancy, tokens/s inputs, latency series).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import dist_ops as D
from ..core import local_ops as L
from ..core import morsel as Mo
from ..core.context import HptmtContext
from ..core.table import narrow_column
from ..models import model as M
from .batcher import SlotBatch
from .metrics import ServingMetrics
from .queue import AdmissionQueue

__all__ = ["Request", "FeatureStore", "ServingEngine"]


# --------------------------------------------------------------------------
# Requests
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    """One serving request: a prompt to decode plus feature-table keys.

    ``status`` walks ``queued -> active -> done`` (or ``rejected`` at the
    admission queue / ``feature_miss`` when a key has no feature row —
    both *counted* terminals, never silent)."""

    req_id: int
    prompt: np.ndarray                      # (L,) int32 token ids
    gen_len: int                            # tokens to generate (>= 1)
    drug_id: int | None = None
    cell_id: int | None = None
    status: str = "new"
    features: dict[str, float] | None = None
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


# --------------------------------------------------------------------------
# Feature store: resident distributed feature table + cached lookup
# --------------------------------------------------------------------------


def _dropped(d) -> int:
    a = np.asarray(d)
    return int(a.max()) if a.size else 0


class FeatureStore:
    """Device-resident distributed feature table with a cached lookup path.

    ``source`` (a column mapping or a :class:`~repro.core.morsel
    .ChunkedTable` — ``np.memmap`` columns stream without copies, so the
    table may exceed device memory) is ingested once: each host morsel is
    hash-shuffled on ``key_col`` and appended into a per-shard resident
    accumulator (``local_ops.append_rows``, buffers donated through the
    chunk loop).  Keys must be unique (run ``dist_unique`` upstream —
    UNOMT Fig. 10 — for sources with duplicates).

    ``lookup(keys)`` then resolves a micro-batch of keys with the same
    decomposition as ``dist_ops.dist_join``: shuffle the probe on the key
    (equal keys co-locate with the resident rows), local inner join per
    shard, collect — but the build-side shuffle is *hoisted out of the
    request path* (done once at ingest), and the probe pipeline is one
    cached :class:`~repro.core.dist_ops.DistributedPipeline` whose static
    probe capacity admits any batch up to ``probe_capacity`` without
    retracing.  ``contains(keys)`` is the matching membership path
    (``dist_isin``'s shuffle + local ``isin``, same hoisting).

    Shuffle/join slabs are sized *skew-proof* for the probe: every key in
    a micro-batch may hash to one shard (hot-key traffic), so
    ``slots_per_dest`` covers a full sender and the receive/output
    capacity covers the whole world's probe rows — lookups never drop.
    Any residual overflow (ingest imbalance past ``overcommit``) is
    counted in ``self.dropped``, never silent.
    """

    def __init__(self, ctx: HptmtContext, key_col: str, source, *,
                 probe_capacity: int, chunk_rows: int | None = None,
                 overcommit: float = 2.0,
                 resident_capacity_per_shard: int | None = None):
        if probe_capacity <= 0:
            raise ValueError("probe_capacity must be positive")
        self.ctx = ctx
        self.key_col = key_col
        self.probe_capacity = int(probe_capacity)
        self.dropped = 0
        world = ctx.world_size

        if isinstance(source, Mo.ChunkedTable):
            src = source
        else:
            cols = {k: np.asarray(v) for k, v in source.items()}
            n = len(next(iter(cols.values())))
            src = Mo.ChunkedTable(cols, chunk_rows or max(n, 1))
        if key_col not in src.names:
            raise ValueError(f"key column {key_col!r} not in source "
                             f"columns {src.names}")
        self.n_rows = src.nrows
        self.feature_cols = tuple(k for k in src.names if k != key_col)

        rcap = resident_capacity_per_shard or max(
            1, math.ceil(src.nrows / world * overcommit))
        acc = D.distribute_table(
            ctx, {k: narrow_column(k, v[:0]) for k, v in
                  src.columns.items()},
            capacity_per_shard=rcap)

        def ingest_step(c, a, chunk):
            # skew-proof slab: a whole morsel may hash to one shard, so a
            # sender may route every row to one dest and a receiver may
            # take the full chunk — ingest itself never drops (only the
            # resident append can overflow, counted, past `overcommit`)
            per = chunk.capacity
            sh, d = D.shuffle(c, chunk, [key_col], slots_per_dest=per,
                              out_capacity=c.world_size * per)
            a2, ad = L.append_rows(a, sh)
            return a2, d + jax.lax.psum(ad, c.row_axes)

        ingest = D.DistributedPipeline(ctx, ingest_step,
                                       donate_argnums=(0,))
        for g in src.distribute(ctx):
            acc, d = ingest(acc, g)
            self.dropped += _dropped(d)
        self.resident = acc

        # probe sizing: a micro-batch of `probe_capacity` keys, every one
        # of which may route to a single shard (skewed/hot keys)
        pcap = max(1, math.ceil(self.probe_capacity / world))
        self._probe_cap_per_shard = pcap
        out_cap = world * pcap

        def lookup_step(c, build, probe):
            sh, d = D.shuffle(c, probe, [key_col], slots_per_dest=pcap,
                              out_capacity=out_cap)
            out, jd = L.join(sh, build, left_on=[key_col], how="inner",
                             out_capacity=out_cap, return_overflow=True)
            return out, d + jax.lax.psum(jd, c.row_axes)

        def contains_step(c, build, probe):
            sh, d = D.shuffle(c, probe, [key_col], slots_per_dest=pcap,
                              out_capacity=out_cap)
            mask, over = L.isin(sh, key_col, build, key_col,
                                return_overflow=True)
            return L.select(sh, mask), \
                d + jax.lax.psum(over, c.row_axes)

        self._lookup = D.DistributedPipeline(ctx, lookup_step)
        self._contains = D.DistributedPipeline(ctx, contains_step)

    # ---------------------------------------------------------------- probes
    def _distribute_probe(self, keys: np.ndarray):
        keys = np.asarray(keys)
        if keys.ndim != 1:
            raise ValueError("keys must be 1-D")
        if len(keys) > self.probe_capacity:
            raise ValueError(f"{len(keys)} keys exceed probe_capacity "
                             f"{self.probe_capacity}")
        probe = {self.key_col: keys.astype(np.int32),
                 "_req": np.arange(len(keys), dtype=np.int32)}
        return D.distribute_table(
            self.ctx, probe,
            capacity_per_shard=self._probe_cap_per_shard)

    def lookup(self, keys: np.ndarray):
        """Resolve ``keys`` -> ``(features, found)``: ``features`` maps each
        feature column to a ``(len(keys),)`` array aligned with ``keys``
        (zeros where missing) and ``found`` flags which keys had a row."""
        k = len(np.asarray(keys))
        out, d = self._lookup(self.resident, self._distribute_probe(keys))
        self.dropped += _dropped(d)
        cols = D.collect_table(self.ctx, out)
        req = cols.pop("_req")
        found = np.zeros(k, bool)
        found[req] = True
        feats = {}
        for name in self.feature_cols:
            buf = np.zeros(k, cols[name].dtype)
            buf[req] = cols[name]
            feats[name] = buf
        return feats, found

    def contains(self, keys: np.ndarray) -> np.ndarray:
        """Membership mask over ``keys`` (the semi-join path — no feature
        materialization)."""
        k = len(np.asarray(keys))
        out, d = self._contains(self.resident,
                                self._distribute_probe(keys))
        self.dropped += _dropped(d)
        cols = D.collect_table(self.ctx, out)
        found = np.zeros(k, bool)
        found[cols["_req"]] = True
        return found


# --------------------------------------------------------------------------
# The engine
# --------------------------------------------------------------------------


class ServingEngine:
    """Admission queue -> feature fetch -> slot prefill -> continuous
    decode, all through cached jitted programs (see module docstring).

    ``feature_stores`` maps a request attribute name (``"drug_id"`` /
    ``"cell_id"``) to the :class:`FeatureStore` resolving it; every store's
    ``probe_capacity`` must admit a full refill micro-batch (``slots``).
    """

    def __init__(self, cfg, params, *, policy=None, slots: int = 4,
                 prompt_capacity: int = 32, gen_capacity: int = 32,
                 queue_capacity: int = 64,
                 feature_stores: Mapping[str, FeatureStore] | None = None,
                 attn_impl: str = "xla", clock=time.perf_counter):
        if cfg.frontend != "none" or cfg.is_encdec:
            raise ValueError("ServingEngine serves decoder-only LM "
                             "configs (no frontend/encoder)")
        self.cfg = cfg
        self.params = params
        self.clock = clock
        self.n_slots = int(slots)
        self.prompt_capacity = int(prompt_capacity)
        self.gen_capacity = int(gen_capacity)
        self.decode_len = self.prompt_capacity + self.gen_capacity
        self.feature_stores = dict(feature_stores or {})
        for name, store in self.feature_stores.items():
            if store.probe_capacity < self.n_slots:
                raise ValueError(
                    f"feature store {name!r} probe_capacity "
                    f"{store.probe_capacity} < slots {self.n_slots}")

        self.metrics = ServingMetrics()
        self.queue = AdmissionQueue(queue_capacity, self.metrics)
        self.batch = SlotBatch(self.n_slots)
        self._finished: list[Request] = []

        # one static-shape cache pytree for the whole engine lifetime
        struct = M.cache_struct(cfg, self.n_slots, self.decode_len)
        self.caches = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), struct)

        prefill = M.make_slot_prefill(cfg, policy,
                                      decode_len=self.decode_len,
                                      attn_impl=attn_impl)

        def prefill_body(params, batch, length):
            logits, caches = prefill(params, batch, length)
            return jnp.argmax(logits, -1).astype(jnp.int32), caches

        serve = M.make_serve_step(cfg, policy, attn_impl=attn_impl)

        def decode_body(params, caches, tokens, cache_lens):
            logits, new_caches = serve(params, caches, tokens, cache_lens)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            return nxt, new_caches

        self._prefill = jax.jit(prefill_body)
        self._insert = jax.jit(M.write_cache_slot, donate_argnums=(0,))
        self._decode = jax.jit(decode_body, donate_argnums=(1,))

    # ------------------------------------------------------------ admission
    def submit(self, req: Request) -> bool:
        """Offer a request to the admission queue.  Returns False (and the
        rejection is counted) under backpressure — the caller may retry."""
        if not (1 <= len(req.prompt) <= self.prompt_capacity):
            raise ValueError(f"prompt length {len(req.prompt)} outside "
                             f"[1, {self.prompt_capacity}]")
        if not (1 <= req.gen_len <= self.gen_capacity):
            raise ValueError(f"gen_len {req.gen_len} outside "
                             f"[1, {self.gen_capacity}]")
        req.t_submit = self.clock()
        ok = self.queue.offer(req)
        req.status = "queued" if ok else "rejected"
        return ok

    # -------------------------------------------------------- feature fetch
    def _fetch_features(self, reqs: list[Request]) -> list[Request]:
        """One batched lookup per store for a refill micro-batch; requests
        whose key has no feature row terminate as counted
        ``feature_miss``es.  Returns the requests that resolved fully."""
        if not self.feature_stores:
            return reqs
        ok = np.ones(len(reqs), bool)
        fetched: dict[int, dict] = {i: {} for i in range(len(reqs))}
        for attr, store in self.feature_stores.items():
            keys = np.asarray([getattr(r, attr) for r in reqs])
            feats, found = store.lookup(keys)
            ok &= found
            self.metrics.inc("feature_rows", int(found.sum()))
            if store.dropped:
                self.metrics.counters["feature_dropped"] = sum(
                    s.dropped for s in self.feature_stores.values())
            for i in range(len(reqs)):
                if found[i]:
                    for name, col in feats.items():
                        fetched[i][name] = float(col[i])
        good = []
        for i, r in enumerate(reqs):
            if ok[i]:
                r.features = fetched[i]
                good.append(r)
            else:
                r.status = "feature_miss"
                r.t_done = self.clock()
                self.metrics.inc("feature_misses")
                self._finished.append(r)
        return good

    # --------------------------------------------------------------- refill
    def _refill(self) -> None:
        free = self.batch.free()
        n = min(len(free), len(self.queue))
        if n == 0:
            return
        reqs = [self.queue.pop() for _ in range(n)]
        reqs = self._fetch_features(reqs)
        for r in reqs:
            slot = self.batch.free()[0]
            prompt_len = len(r.prompt)
            padded = np.zeros((1, self.prompt_capacity), np.int32)
            padded[0, :prompt_len] = r.prompt
            first, one = self._prefill(
                self.params, {"tokens": jnp.asarray(padded)},
                jnp.int32(prompt_len))
            self.caches = self._insert(self.caches, one, jnp.int32(slot))
            first_tok = int(first[0])
            now = self.clock()
            r.t_admit = now
            r.t_first = now
            r.status = "active"
            r.out_tokens.append(first_tok)
            self.metrics.inc("admitted")
            self.metrics.inc("prefills")
            self.metrics.inc("tokens_generated")
            self.metrics.observe("queue_wait", now - r.t_submit)
            self.metrics.observe("ttft", now - r.t_submit)
            if r.gen_len == 1:          # prefill's token was the answer
                self._complete(r)
                continue
            self.batch.occupy(slot, r, first_token=first_tok,
                              prompt_len=prompt_len, gen_target=r.gen_len)
        self.metrics.gauge("slot_occupancy", self.batch.occupancy)

    def _complete(self, r: Request) -> None:
        r.status = "done"
        r.t_done = self.clock()
        self.metrics.inc("completed")
        self.metrics.observe("latency", r.t_done - r.t_submit)
        self._finished.append(r)

    # ----------------------------------------------------------------- step
    def step(self) -> list[Request]:
        """Refill freed slots from the queue, run one decode step over the
        fixed-shape batch, and return the requests that finished."""
        self._refill()
        active = self.batch.active()
        if active:
            nxt, self.caches = self._decode(
                self.params, self.caches,
                jnp.asarray(self.batch.tokens),
                jnp.asarray(self.batch.cache_lens))
            nxt = np.asarray(nxt)
            self.metrics.inc("decode_steps")
            self.metrics.inc("tokens_generated", len(active))
            finished = self.batch.advance(
                nxt, on_token=lambda s, r, t: r.out_tokens.append(t))
            for slot in finished:
                self._complete(self.batch.release(slot))
            self.metrics.gauge("slot_occupancy", self.batch.occupancy)
        done, self._finished = self._finished, []
        return done

    @property
    def busy(self) -> bool:
        return bool(len(self.queue) or self.batch.active())

    def run_until_drained(self, max_steps: int = 1_000_000):
        """Step until the queue and every slot are empty; returns all
        requests that finished along the way."""
        out = []
        steps = 0
        while self.busy:
            out.extend(self.step())
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"engine not drained after "
                                   f"{max_steps} steps")
        return out
