import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
# backend init).  This file is the ONLY place the 512-device placeholder
# mesh is created (assignment MULTI-POD DRY-RUN step 0).

"""Multi-pod dry-run driver.

For every (architecture × input shape × mesh) combination, build the step
function (train_step / prefill / serve_step), ``.lower().compile()`` it
against ShapeDtypeStruct stand-ins (no allocation), print
``memory_analysis()`` / ``cost_analysis()``, and record the roofline terms
(repro.roofline) into a JSON results file.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch qwen3-moe-235b-a22b] [--cell train_4k] [--mesh both]
        [--out results/dryrun.json] [--overrides k=v,...]

Results accumulate incrementally; cells already present are skipped unless
--force.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from ..configs import ARCH_IDS, SHAPES, cells_for, get_config
from ..models import model as M
from ..models.sharding import make_policy
from ..optim import adamw
from ..roofline.analysis import analyze
from . import specs as SP
from .mesh import make_production_mesh


def build_lowered(cfg, cell: str, mesh, *, donate: bool = True):
    """Lower the cell's step function on `mesh`; returns jax Lowered."""
    kind = SHAPES[cell].kind
    if kind == "train":
        policy = make_policy(mesh, cfg.train.sharding)
        opt_cfg = adamw.AdamWConfig(
            moment_dtype=cfg.train.opt_dtype)
        sp = SP.input_specs(cfg, cell, policy, opt_cfg)
        step = M.make_train_step(cfg, policy, opt_cfg)
        fn = jax.jit(step, donate_argnums=(0, 1) if donate else ())
        return fn.lower(sp["params"], sp["opt_state"], sp["batch"])
    if kind == "prefill":
        policy = make_policy(mesh, "fsdp_tp")        # serving: 2D weights
        sp = SP.input_specs(cfg, cell, policy)
        sh = SHAPES[cell]
        prefill = M.make_prefill(cfg, policy, decode_len=sh.seq_len)
        fn = jax.jit(prefill)
        return fn.lower(sp["params"], sp["batch"])
    # decode
    policy = make_policy(mesh, "fsdp_tp")
    sp = SP.input_specs(cfg, cell, policy)
    serve = M.make_serve_step(cfg, policy)
    fn = jax.jit(serve, donate_argnums=(1,) if donate else ())
    return fn.lower(sp["params"], sp["caches"], sp["tokens"],
                    sp["cache_len"])


def run_cell(arch: str, cell: str, multi_pod: bool, overrides=None):
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(
            cfg, train=dataclasses.replace(cfg.train, **overrides))
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    mesh_desc = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    lowered = build_lowered(cfg, cell, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    roof = analyze(compiled, arch=arch, cell=cell, mesh_desc=mesh_desc,
                   n_chips=n_chips, cfg=cfg)
    rec = roof.to_dict()
    rec["lower_s"] = round(t1 - t0, 2)
    rec["compile_s"] = round(t2 - t1, 2)
    rec["ok"] = True
    # the assignment asks these to be printed:
    try:
        print(compiled.memory_analysis())
    except Exception as e:            # pragma: no cover
        print("memory_analysis unavailable:", e)
    cost = compiled.cost_analysis()
    print({k: v for k, v in (cost[0] if isinstance(cost, list)
                             else cost).items()
           if k in ("flops", "bytes accessed")})
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (default: all)")
    ap.add_argument("--cell", default=None,
                    help="shape cell (default: all for the arch)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--overrides", default=None,
                    help="TrainSettings overrides k=v[,k=v...] "
                         "(ints/floats/strs)")
    args = ap.parse_args()

    overrides = None
    if args.overrides:
        overrides = {}
        for kv in args.overrides.split(","):
            k, v = kv.split("=")
            if v in ("True", "true"):
                v = True
            elif v in ("False", "false"):
                v = False
            else:
                try:
                    v = int(v)
                except ValueError:
                    try:
                        v = float(v)
                    except ValueError:
                        pass
            overrides[k] = v

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        cells = [args.cell] if args.cell else list(cells_for(arch))
        for cell in cells:
            for mp in meshes:
                mesh_desc = "2x16x16" if mp else "16x16"
                key = f"{args.tag}/{arch}/{cell}/{mesh_desc}"
                if key in results and results[key].get("ok") \
                        and not args.force:
                    print(f"[skip] {key}")
                    continue
                print(f"[run ] {key}", flush=True)
                try:
                    rec = run_cell(arch, cell, mp, overrides)
                except Exception as e:
                    rec = {"ok": False, "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"[FAIL] {key}: {rec['error']}", flush=True)
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                if rec.get("ok"):
                    print(f"[ok  ] {key} compute={rec['compute_s']:.4f}s "
                          f"memory={rec['memory_s']:.4f}s "
                          f"collective={rec['collective_s']:.4f}s "
                          f"bound={rec['bound']} "
                          f"(compile {rec['compile_s']}s)", flush=True)

    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"done: {n_ok}/{len(results)} cells ok -> {args.out}")


if __name__ == "__main__":
    main()
