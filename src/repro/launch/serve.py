"""Serving launcher: continuous-batching decode fused with feature joins.

    PYTHONPATH=src python -m repro.launch.serve --arch lm100m --reduced \
        [--requests 32] [--slots 4] [--prompt-len 32] [--gen 16] \
        [--queue-capacity 64] [--no-features] [--mesh data=1,model=2]

Thin CLI over :class:`repro.serving.ServingEngine`: generates a stream of
requests (random prompts of *heterogeneous* lengths, each carrying
drug/cell feature keys), submits them through the bounded admission
queue, and runs the engine until drained — continuous batching refills
freed decode slots while the rest of the batch keeps generating, and
every request's keys resolve against UNOMT feature tables through the
distributed join path before its prompt enters a slot.  Prints the full
metrics snapshot (counters / gauges / latency summaries) and asserts the
accounting identity: submitted == completed + rejected + feature_misses.
"""
import argparse
import os
import sys
import time


_COUNT_FLAG = "--xla_force_host_platform_device_count"


def ensure_host_devices(n: int, module: str) -> None:
    """Re-exec with ``XLA_FLAGS`` requesting ``n`` host devices — *merging*
    with any flags already set (replacing a stale device-count flag,
    keeping everything else) instead of skipping when ``XLA_FLAGS``
    exists.  No-op (so the re-exec terminates) once the flag is right."""
    want = f"{_COUNT_FLAG}={n}"
    flags = os.environ.get("XLA_FLAGS", "").split()
    if want in flags:
        return
    flags = [f for f in flags if not f.startswith(_COUNT_FLAG)]
    os.environ["XLA_FLAGS"] = " ".join(flags + [want])
    os.execv(sys.executable,
             [sys.executable, "-m", module] + sys.argv[1:])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="max prompt length (requests vary below it)")
    ap.add_argument("--gen", type=int, default=16,
                    help="max tokens generated (requests vary below it)")
    ap.add_argument("--queue-capacity", type=int, default=64)
    ap.add_argument("--no-features", action="store_true",
                    help="skip the feature-store stage")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.mesh:
        n = 1
        for kv in args.mesh.split(","):
            n *= int(kv.split("=")[1])
        ensure_host_devices(n, "repro.launch.serve")

    import jax
    import numpy as np

    from ..configs import get_config, get_reduced
    from ..core.context import make_context
    from ..data.unomt import gen_unomt_tables
    from ..models import model as M
    from ..models.sharding import make_policy
    from ..serving import FeatureStore, Request, ServingEngine

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    policy = None
    if args.mesh:
        shape = {kv.split("=")[0]: int(kv.split("=")[1])
                 for kv in args.mesh.split(",")}
        mesh = jax.make_mesh(tuple(shape.values()), tuple(shape.keys()))
        policy = make_policy(mesh, "fsdp_tp")

    params = M.init_params(jax.random.PRNGKey(0), cfg)

    stores = {}
    n_drugs, n_cells = 256, 128
    if not args.no_features:
        ctx = make_context()
        raw = gen_unomt_tables(n_drugs=n_drugs, n_cells=n_cells,
                               seed=args.seed)
        drug = dict(raw["descriptors"])
        drug.update({k: v for k, v in raw["fingerprints"].items()
                     if k != "drug_id"})
        # rna carries duplicate records (paper: drop-duplicates) — keep
        # the first row per key so store keys are unique
        _, first = np.unique(raw["rna"]["cell_id"], return_index=True)
        rna = {k: v[first] for k, v in raw["rna"].items()}
        cap = max(args.slots, 8)
        stores = {
            "drug_id": FeatureStore(ctx, "drug_id", drug,
                                    probe_capacity=cap, chunk_rows=64),
            "cell_id": FeatureStore(ctx, "cell_id", rna,
                                    probe_capacity=cap, chunk_rows=64),
        }

    engine = ServingEngine(cfg, params, policy=policy, slots=args.slots,
                           prompt_capacity=args.prompt_len,
                           gen_capacity=args.gen,
                           queue_capacity=args.queue_capacity,
                           feature_stores=stores)

    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    rejected_ids = []
    for i in range(args.requests):
        req = Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab,
                                rng.integers(1, args.prompt_len + 1)
                                ).astype(np.int32),
            gen_len=int(rng.integers(1, args.gen + 1)),
            drug_id=int(rng.integers(0, n_drugs)),
            cell_id=int(rng.integers(0, n_cells)))
        if not engine.submit(req):
            rejected_ids.append(i)
        if (i + 1) % max(args.slots * 4, 8) == 0:
            engine.step()                  # interleave arrivals and decode
    done = engine.run_until_drained()
    dt = time.perf_counter() - t0

    m = engine.metrics
    snap = m.snapshot()
    print(f"[serve] {len(done)} completed / {len(rejected_ids)} rejected "
          f"of {args.requests} in {dt:.2f}s "
          f"({m.count('tokens_generated') / dt:.0f} tok/s)")
    for k in sorted(snap["counters"]):
        print(f"  counter {k:>18} = {snap['counters'][k]}")
    for k, g in snap["gauges"].items():
        print(f"  gauge   {k:>18} = last {g['last']:.0f} max {g['max']:.0f}")
    for k, s in snap["latency"].items():
        if s["count"]:
            print(f"  series  {k:>18} = p50 {s['p50'] * 1e3:.1f}ms "
                  f"p99 {s['p99'] * 1e3:.1f}ms n={s['count']}")
    assert m.count("submitted") == m.count("completed") + \
        m.count("rejected") + m.count("feature_misses"), \
        "accounting identity violated"
    for r in done:
        assert len(r.out_tokens) == r.gen_len, (r.req_id, r.status)
        if stores and r.status == "done":
            assert r.features, f"request {r.req_id} served without features"
    print("serve OK")


if __name__ == "__main__":
    main()
