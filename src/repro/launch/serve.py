"""Serving launcher: batched prefill + decode with a static KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch lm100m --reduced \
        [--batch 4] [--prompt-len 32] [--gen 16] [--mesh data=1,model=2]

Runs continuous batched greedy decoding and reports tokens/s.  The same
``serve_step`` is what the decode_32k / long_500k dry-run cells lower on
the production mesh.
"""
import argparse
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()

    if args.mesh and "XLA_FLAGS" not in os.environ:
        n = 1
        for kv in args.mesh.split(","):
            n *= int(kv.split("=")[1])
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n}"
        os.execv(sys.executable,
                  [sys.executable, "-m", "repro.launch.serve"] + sys.argv[1:])

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs import get_config, get_reduced
    from ..models import model as M
    from ..models.sharding import make_policy

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    policy = None
    if args.mesh:
        shape = {kv.split("=")[0]: int(kv.split("=")[1])
                 for kv in args.mesh.split(",")}
        mesh = jax.make_mesh(tuple(shape.values()), tuple(shape.keys()))
        policy = make_policy(mesh, "fsdp_tp")

    B, P_len, G = args.batch, args.prompt_len, args.gen
    decode_len = P_len + G
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prefill = jax.jit(M.make_prefill(cfg, policy, decode_len=decode_len))
    serve = jax.jit(M.make_serve_step(cfg, policy), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, P_len)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.zeros(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec:
        batch["frames"] = jnp.zeros(
            (B, P_len // cfg.enc_len_ratio, cfg.d_model), jnp.bfloat16)

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"[prefill] {B}x{P_len} tokens in {t_prefill:.3f}s "
          f"({B * P_len / t_prefill:.0f} tok/s)")

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    outs = [tok]
    t0 = time.perf_counter()
    for i in range(G - 1):
        logits, caches = serve(params, caches, tok,
                               jnp.int32(P_len + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    print(f"[decode] {B}x{G - 1} tokens in {dt:.3f}s "
          f"({B * (G - 1) / max(dt, 1e-9):.0f} tok/s)")
    print(f"[sample] first sequence: {gen[0][:12].tolist()}")
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print("serve OK")


if __name__ == "__main__":
    main()
