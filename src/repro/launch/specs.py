"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

The same pattern shannon/kernels uses: weak-type-correct, shardable, no
device allocation.  Every struct carries its NamedSharding so
``jit(...).lower(**specs)`` sees the intended distribution."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, ArchConfig
from ..models import model as M
from ..models.sharding import Policy
from ..optim import adamw

F32 = jnp.float32


def _sds(shape, dtype, policy: Policy, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=policy.named(spec))


def _batch_spec(policy: Policy, B: int) -> P:
    """Shard batch over (pod, data) when divisible, else replicate."""
    world_b = 1
    for a in policy.batch_axes:
        world_b *= policy.mesh.shape[a]
    return P(policy.batch_axes) if B % world_b == 0 else P(None)


def train_batch_specs(cfg: ArchConfig, cell: str, policy: Policy):
    sh = SHAPES[cell]
    B, S = sh.global_batch, sh.seq_len
    bs = _batch_spec(policy, B)
    d = {
        "tokens": _sds((B, S), jnp.int32, policy, P(*bs, None)),
        "labels": _sds((B, S), jnp.int32, policy, P(*bs, None)),
    }
    if cfg.frontend == "vision":
        d["patch_embeds"] = _sds((B, cfg.frontend_tokens, cfg.d_model),
                                 jnp.bfloat16, policy, P(*bs, None, None))
    if cfg.is_encdec:
        d["frames"] = _sds((B, S // cfg.enc_len_ratio, cfg.d_model),
                           jnp.bfloat16, policy, P(*bs, None, None))
    return d


def prefill_batch_specs(cfg: ArchConfig, cell: str, policy: Policy):
    d = train_batch_specs(cfg, cell, policy)
    d.pop("labels")
    return d


def cache_specs(cfg: ArchConfig, cell: str, policy: Policy):
    """Decode-shape KV/SSM cache stand-ins, seq sharded over model."""
    sh = SHAPES[cell]
    B, S = sh.global_batch, sh.seq_len
    enc_len = S // cfg.enc_len_ratio if cfg.is_encdec else 0
    struct = M.cache_struct(cfg, B, S, enc_len)
    bs = _batch_spec(policy, B)
    m = policy.model_axis

    def spec_for(path, s):
        name = path[-1].key
        if name in ("k", "v", "ck", "cv"):     # (L, B, H, S, D)
            return P(None, *bs, None, m, None)
        if name == "conv":                      # (L, B, K-1, E)
            return P(None, *bs, None, m)
        if name == "ssm":                       # (L, B, E, N)
            return P(None, *bs, m, None)
        return P()

    return jax.tree_util.tree_map_with_path(
        lambda path, s: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=policy.named(spec_for(path, s))),
        struct)


def decode_token_specs(cfg: ArchConfig, cell: str, policy: Policy):
    sh = SHAPES[cell]
    B = sh.global_batch
    bs = _batch_spec(policy, B)
    return (_sds((B, 1), jnp.int32, policy, P(*bs, None)),
            jax.ShapeDtypeStruct((), jnp.int32))


def param_specs(cfg: ArchConfig, policy: Policy):
    shapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    shardings = policy.param_shardings(shapes)
    return jax.tree_util.tree_map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
        shapes, shardings)


def opt_state_specs(cfg: ArchConfig, policy: Policy, params_sds,
                    opt_cfg: adamw.AdamWConfig):
    shapes = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), params_sds)
    use2d = cfg.train.use_zero1 or cfg.train.sharding == "fsdp_tp"

    def shard(tree):
        sh = policy.param_shardings(tree, use2d=use2d)
        return jax.tree_util.tree_map(
            lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                               sharding=ns), tree, sh)

    return {
        "m": shard(shapes["m"]),
        "v": shard(shapes["v"]),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_specs(cfg: ArchConfig, cell: str, policy: Policy,
                opt_cfg: adamw.AdamWConfig | None = None) -> dict[str, Any]:
    """Everything needed to lower the cell's step function."""
    kind = SHAPES[cell].kind
    out: dict[str, Any] = {"kind": kind}
    params = param_specs(cfg, policy)
    out["params"] = params
    if kind == "train":
        out["batch"] = train_batch_specs(cfg, cell, policy)
        out["opt_state"] = opt_state_specs(
            cfg, policy, params, opt_cfg or adamw.AdamWConfig())
    elif kind == "prefill":
        out["batch"] = prefill_batch_specs(cfg, cell, policy)
    else:
        out["caches"] = cache_specs(cfg, cell, policy)
        tok, clen = decode_token_specs(cfg, cell, policy)
        out["tokens"], out["cache_len"] = tok, clen
    return out
