"""Training launcher — the paper's single-command spawn (``mpirun``
equivalent) for LM training with the full fault-tolerance stack.

    PYTHONPATH=src python -m repro.launch.train --arch lm100m \
        [--steps 300] [--batch 8] [--seq 512] [--reduced]
        [--mesh data=2,model=2]        # forced host devices (re-execs)
        [--ckpt-dir /tmp/lm_ckpt] [--ckpt-every 50]
        [--fail-at 120]                # failure-injection drill
        [--resume]                     # restore latest checkpoint

On a real multi-host cluster, run this same script once per host with
``jax.distributed.initialize()`` (the ``--coordinator`` flag) — the mesh
logic and the step function are identical; the SPMD program does not
change (loosely-synchronous model: no central scheduler).
"""
import argparse
import os
import sys


def _parse_mesh(s: str) -> dict:
    out = {}
    for kv in s.split(","):
        k, v = kv.split("=")
        out[k] = int(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm100m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the arch's reduced() smoke config")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--mesh", default=None,
                    help="e.g. data=2,model=2 (forces host devices)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--coordinator", default=None,
                    help="host:port for jax.distributed.initialize "
                         "(real clusters)")
    args = ap.parse_args()

    mesh_shape = _parse_mesh(args.mesh) if args.mesh else None
    if mesh_shape and "XLA_FLAGS" not in os.environ:
        n = 1
        for v in mesh_shape.values():
            n *= v
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n}"
        os.execv(sys.executable,
                  [sys.executable, "-m", "repro.launch.train"] + sys.argv[1:])

    import jax
    if args.coordinator:
        jax.distributed.initialize(coordinator_address=args.coordinator)
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import get_config, get_reduced
    from ..data.synthetic import lm_batch_at
    from ..models import model as M
    from ..models.sharding import make_policy
    from ..optim import adamw
    from ..runtime.trainer import FailureInjector, Trainer, \
        run_with_restarts

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if mesh_shape:
        mesh = jax.make_mesh(tuple(mesh_shape.values()),
                             tuple(mesh_shape.keys()))
        policy = make_policy(mesh, cfg.train.sharding)
    else:
        mesh, policy = None, None
    print(f"[launch] arch={cfg.name} params={cfg.param_count():,} "
          f"mesh={mesh_shape or 'single-device'}")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init(params, opt_cfg)
    if policy is not None:
        shardings = policy.param_shardings(params)
        params = jax.tree_util.tree_map(jax.device_put, params, shardings)
        # optimizer state: ZeRO-1 2D layout; step scalar replicated so the
        # elastic restore template carries mesh-wide shardings end to end
        opt_sh = policy.param_shardings(params, for_opt=True)
        opt_state = {
            "m": jax.tree_util.tree_map(jax.device_put, opt_state["m"],
                                        opt_sh),
            "v": jax.tree_util.tree_map(jax.device_put, opt_state["v"],
                                        opt_sh),
            "step": jax.device_put(opt_state["step"],
                                   NamedSharding(mesh, P())),
        }
    raw_step = M.make_train_step(cfg, policy, opt_cfg)
    jit_step = jax.jit(raw_step, donate_argnums=(0, 1))

    def step_fn(state, batch):
        params, opt = state
        params, opt, metrics = jit_step(params, opt, batch)
        return (params, opt), metrics

    if mesh is not None:
        bsharding = NamedSharding(mesh, P(policy.batch_axes, None))
    else:
        bsharding = None

    def batches(start):
        s = start
        while True:
            b = lm_batch_at(s, vocab=cfg.vocab, batch=args.batch,
                            seq=args.seq)
            b = {k: jnp.asarray(v) for k, v in b.items()}
            if bsharding is not None:
                b = {k: jax.device_put(v, bsharding)
                     for k, v in b.items()}
            yield b
            s += 1

    trainer = Trainer(step_fn=step_fn, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every,
                      failure=FailureInjector(args.fail_at))
    state0 = (params, opt_state)
    if not args.resume:
        # fresh run: clear stale checkpoints so step counting is honest
        import shutil
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    state, history = run_with_restarts(batches, trainer, state0,
                                       n_steps=args.steps)
    print(f"[done] loss {history[0]['loss']:.4f} -> "
          f"{history[-1]['loss']:.4f} over {len(history)} recorded steps")
    if trainer.monitor.stragglers:
        print(f"[monitor] stragglers flagged: "
              f"{trainer.monitor.stragglers[:5]}")


if __name__ == "__main__":
    main()
