"""Parse collective traffic out of post-SPMD HLO text.

``compiled.cost_analysis()`` has FLOPs/bytes but no collective bytes — we
regex the per-partition HLO module for all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops, take the *result*
shape bytes (per-device), recover the participant group size from
``replica_groups`` (both explicit ``{{0,1,..}}`` and iota
``[g,n]<=[...]`` formats), and convert to per-device link bytes with the
standard ring cost factors.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")

_LINE_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    result_bytes: dict          # per-device result bytes by op kind
    link_bytes: dict            # ring-model per-device link bytes by kind

    @property
    def total_link_bytes(self) -> float:
        return float(sum(self.link_bytes.values()))

    @property
    def total_result_bytes(self) -> float:
        return float(sum(self.result_bytes.values()))


def _ring_factor(op: str, group: int) -> float:
    if op == "collective-permute":
        return 1.0              # one hop of the full result, no groups attr
    if group <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (group - 1) / group
    if op == "all-gather":
        return float(group - 1) / group
    if op == "reduce-scatter":
        # result is the scattered shard; bytes moved ~ (group-1) * result
        return float(group - 1)
    if op == "all-to-all":
        return float(group - 1) / group
    return 1.0                  # collective-permute: one hop


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict = defaultdict(int)
    result_bytes: dict = defaultdict(float)
    link_bytes: dict = defaultdict(float)
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        op = m.group(4)
        # async pairs: count -start, skip -done (same traffic)
        if f"{op}-done(" in line:
            continue
        if m.group(1) is not None:          # tuple result
            b = sum(_shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(m.group(1)))
        else:
            b = _shape_bytes(m.group(2), m.group(3))
        g = 1
        mg = _GROUPS_RE.search(line)
        if mg:
            g = len(mg.group(1).split(","))
        else:
            mi = _IOTA_RE.search(line)
            if mi:
                g = int(mi.group(2))
        counts[op] += 1
        result_bytes[op] += b
        link_bytes[op] += b * _ring_factor(op, g)
    return CollectiveStats(dict(counts), dict(result_bytes),
                           dict(link_bytes))
