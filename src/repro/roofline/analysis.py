"""Three-term roofline from a compiled dry-run artifact.

Constants (assignment): TPU v5e-like — 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.

Terms (seconds/step, per chip — cost_analysis of the post-SPMD module is
the per-partition program, so its FLOPs/bytes are already per-device;
dividing by per-chip peaks is equivalent to the assignment's
``HLO_FLOPs/(chips × peak)`` with global HLO_FLOPs):

    compute    = flops_per_device / PEAK_FLOPS
    memory     = bytes_per_device / HBM_BW
    collective = ring-model link bytes per device / ICI_BW
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

from .hlo import CollectiveStats, parse_collectives
from .hlo_cost import analyze_hlo_text

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link


@dataclasses.dataclass
class Roofline:
    arch: str
    cell: str
    mesh: str
    flops_per_dev: float
    bytes_per_dev: float
    collective: CollectiveStats
    model_flops: float                   # 6ND (train) / 2ND (inference)
    n_chips: int
    memory_per_dev: dict | None = None
    xla_flops: float = 0.0               # HloCostAnalysis (while body x1 —
    xla_bytes: float = 0.0               # kept for reference only)

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective.total_link_bytes / ICI_BW

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        hlo_global = self.flops_per_dev * self.n_chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        denom = self.step_s * PEAK_FLOPS * self.n_chips
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict[str, Any]:
        return {
            "arch": self.arch, "cell": self.cell, "mesh": self.mesh,
            "n_chips": self.n_chips,
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "collective_result_bytes": self.collective.result_bytes,
            "collective_link_bytes": self.collective.link_bytes,
            "collective_counts": self.collective.counts,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bound": self.bound,
            "step_s": self.step_s,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu": self.mfu,
            "memory_per_dev": self.memory_per_dev,
            "xla_flops": self.xla_flops,
            "xla_bytes": self.xla_bytes,
        }


def model_flops_for(cfg, cell_name: str) -> float:
    """6·N_active·D for train, 2·N_active·D for inference steps."""
    from ..configs import SHAPES
    sh = SHAPES[cell_name]
    n = cfg.active_param_count()
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n * tokens
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n * tokens
    tokens = sh.global_batch            # one token per sequence
    return 2.0 * n * tokens


def analyze(compiled, *, arch: str, cell: str, mesh_desc: str,
            n_chips: int, cfg) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older API returned [dict]
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    # trip-count-aware costs (XLA counts while bodies once; a scanned
    # 80-layer stack would be ~80x undercounted) — see hlo_cost.py
    hc = analyze_hlo_text(compiled.as_text())
    flops = float(hc.flops)
    byts = float(hc.bytes)
    stats = hc.collective_stats()
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(ma, "generated_code_size_in_bytes", None),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
        }
    except Exception:
        pass
    return Roofline(arch=arch, cell=cell, mesh=mesh_desc,
                    flops_per_dev=flops, bytes_per_dev=byts,
                    collective=stats,
                    model_flops=model_flops_for(cfg, cell),
                    n_chips=n_chips, memory_per_dev=mem,
                    xla_flops=xla_flops, xla_bytes=xla_bytes)
