"""Trip-count-aware cost model over post-optimization HLO text.

``compiled.cost_analysis()`` (XLA HloCostAnalysis) counts a ``while`` body
**once**, so any scanned program (scan-over-layers, microbatch accumulation,
chunked attention/loss) under-reports FLOPs/bytes/collective traffic by the
trip count — for a 94-layer scanned stack that is a ~94x error in every
roofline term.  This module re-derives the three costs from
``compiled.as_text()`` with ``while`` bodies multiplied by their
``known_trip_count`` backend config (falling back to the loop-condition
constant), which XLA attaches to all ``lax.scan``/``fori_loop`` lowerings.

Accounting conventions (mirrors HloCostAnalysis at fusion granularity):
* dot: ``2 * prod(output_dims) * prod(contracted_dims)`` FLOPs;
* other non-trivial ops: 1 FLOP per output element;
* bytes: per top-level kernel (fusion or unfused op) = operand bytes +
  output bytes; fusion-internal ops contribute FLOPs but no bytes;
* collectives: result-shape bytes with ring-model link factors
  (see ``hlo.py``), multiplied by the enclosing loops' trip counts.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

from .hlo import _DTYPE_BYTES, CollectiveStats, _ring_factor

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],\s{}]+?))\s+"
    r"([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count=\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONST_CMP_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops with no arithmetic/traffic of their own
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "bitcast-convert", "reshape", "after-all", "partition-id",
         "replica-id", "iota", "custom-call"}

# ops that touch only their *output*-sized window of the operand (XLA's
# HloCostAnalysis convention): billing the full operand would overcount a
# scan body's dynamic-slice of stacked layer weights by n_layers x.
_SLICING = {"dynamic-slice", "gather", "slice"}
_UPDATING = {"dynamic-update-slice", "scatter"}


def _parse_shapes(type_str: str):
    """'(s32[], bf16[2,3]{1,0})' or 'f32[4,4]{1,0}' -> [(dtype, dims)]."""
    return [(dt, tuple(int(d) for d in dims.split(",") if d))
            for dt, dims in _SHAPE_RE.findall(type_str)]


def _shape_bytes(shapes) -> int:
    return sum(_DTYPE_BYTES.get(dt, 4) * _prod(dims) for dt, dims in shapes)


def _elems(shapes) -> int:
    return sum(_prod(dims) for dims, in [(d,) for _, d in shapes])


def _prod(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    out_shapes: list            # [(dtype, dims)]
    line: str


@dataclasses.dataclass
class _Comp:
    name: str
    params: dict                # name -> [(dtype, dims)]
    ops: list                   # [_Op]
    symbols: dict               # name -> [(dtype, dims)]
    defs: dict = dataclasses.field(default_factory=dict)  # name -> _Op


def parse_module(hlo_text: str) -> dict:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and ("->" in line):
                name, params_str, _ret = m.groups()
                params = {}
                for pm in re.finditer(r"%?([\w\.\-]+):\s*"
                                      r"((?:\([^)]*\)|[\w\[\],{}]+))",
                                      params_str):
                    params[pm.group(1)] = _parse_shapes(pm.group(2))
                cur = _Comp(name=name, params=params, ops=[],
                            symbols=dict(params))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        opname, type_str, opcode, _rest = m.groups()
        shapes = _parse_shapes(type_str)
        op = _Op(name=opname, opcode=opcode, out_shapes=shapes, line=line)
        cur.ops.append(op)
        cur.symbols[opname] = shapes
        cur.defs[opname] = op
    return comps


def _is_pure_convert_body(body: "_Comp") -> bool:
    real = [o for o in body.ops if o.opcode != "parameter"]
    return len(real) == 1 and real[0].opcode == "convert"


def _wire_factor(op: _Op, comp: _Comp, comps: dict) -> float:
    """Target wire-bytes correction for a collective.

    The XLA *CPU* backend's float normalization legalizes bf16
    collectives to f32, wrapping the operand in a pure bf16->f32 convert
    (``wrapped_convert`` fusion or a bare convert).  On the TPU target
    the wire stays bf16 — bill half the bytes when the pattern is
    detected.  (Verified: a bf16 ``psum`` compiles on CPU to exactly
    convert -> f32 all-reduce -> convert.)"""
    names = _operands(op)
    if not names:
        return 1.0
    d = comp.defs.get(names[0])
    if d is None:
        return 1.0
    if d.opcode == "convert":
        src = _operands(d)
        if src and comp.symbols.get(src[0], [("", ())])[0][0] == "bf16":
            return 0.5
        return 1.0
    if d.opcode == "fusion":
        m = _CALLS_RE.search(d.line)
        body = comps.get(m.group(1)) if m else None
        if body is not None and _is_pure_convert_body(body):
            ptypes = [s[0][0] for s in body.params.values() if s]
            if ptypes and all(t == "bf16" for t in ptypes):
                return 0.5
    return 1.0


def _dot_flops(op: _Op, comp: _Comp) -> float:
    out_elems = _prod(op.out_shapes[0][1]) if op.out_shapes else 0
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    # first operand after '(' is the lhs
    paren = op.line.split(op.opcode + "(", 1)[1]
    ops_m = _OPERAND_RE.findall(paren)
    contracted = 1
    if mc and ops_m:
        lhs = comp.symbols.get(ops_m[0])
        if lhs:
            dims = lhs[0][1]
            for idx in (int(i) for i in mc.group(1).split(",") if i):
                if idx < len(dims):
                    contracted *= dims[idx]
    return 2.0 * out_elems * contracted


def _operands(op: _Op) -> list:
    paren = op.line.split(op.opcode + "(", 1)[1]
    out, seen = [], set()
    for name in _OPERAND_RE.findall(paren.split("), ")[0] + ")"):
        if name not in seen:
            seen.add(name)
            out.append(name)
    return out


def _operand_bytes(op: _Op, comp: _Comp) -> int:
    total = 0
    for name in _operands(op):
        shapes = comp.symbols.get(name)
        if shapes:
            total += _shape_bytes(shapes)
    return total


def _kernel_bytes(op: _Op, comp: _Comp) -> int:
    """HBM traffic of one top-level kernel, with slicing ops billed at
    their accessed window, not the full operand buffer."""
    out_b = _shape_bytes(op.out_shapes)
    if op.opcode in _SLICING:
        return 2 * out_b                       # read window + write out
    if op.opcode in _UPDATING:
        names = _operands(op)
        upd = names[1] if len(names) > 1 else None
        upd_b = _shape_bytes(comp.symbols.get(upd, [])) if upd else out_b
        return 2 * upd_b                       # read + write the window
    return _operand_bytes(op, comp) + out_b


# dtype/layout pass-through ops: a window access seen through these is
# still a window access (the TPU target keeps dus in place; the CPU
# backend's convert-around-dus quirk must not bill the full buffer)
_PASSTHRU = {"convert", "bitcast", "copy", "bitcast-convert"}


def _transitive_consumers(body: "_Comp", name: str, depth: int = 0):
    """Consumers of `name` inside the fusion body, looking through
    dtype/layout pass-through ops.  Yields (_Op, via_operand_index)."""
    if depth > 6:
        return
    for bop in body.ops:
        if bop.opcode == "parameter":
            continue
        ops_list = _operands(bop)
        if name not in ops_list:
            continue
        if bop.opcode in _PASSTHRU:
            yield from _transitive_consumers(body, bop.name, depth + 1)
            # a pass-through that IS the fusion root still forwards the
            # buffer; treated as window-neutral
        else:
            yield bop, ops_list.index(name)


def _fusion_bytes(op: _Op, comp: _Comp, body: "_Comp") -> int:
    """Fusion traffic = output + per-parameter accessed bytes.  A param
    consumed ONLY by slicing/updating ops (possibly through converts) is
    billed at the accessed windows — the stacked-layer-weights /
    residual-stash patterns of scans."""
    out_b = _shape_bytes(op.out_shapes)
    operand_names = _operands(op)
    param_names = list(body.params.keys())
    dus_root = any(b.opcode in _UPDATING for b in body.ops)
    total = out_b
    for i, pname in enumerate(param_names):
        full = _shape_bytes(body.params[pname])
        if i < len(operand_names):
            oshapes = comp.symbols.get(operand_names[i])
            if oshapes:
                full = _shape_bytes(oshapes)
        accessed, only_windows, used = 0, True, False
        for bop, op_idx in _transitive_consumers(body, pname):
            used = True
            if bop.opcode in _SLICING and op_idx == 0:
                accessed += _shape_bytes(bop.out_shapes)
            elif bop.opcode in _UPDATING and op_idx == 0:
                names = _operands(bop)
                upd = names[1] if len(names) > 1 else None
                accessed += _shape_bytes(
                    body.symbols.get(upd, bop.out_shapes))
            else:
                only_windows = False
        if used and only_windows and accessed:
            total += min(accessed, full)
        elif used:
            total += full
        # unused params (pure pass-through to the root, e.g. aliased dus
        # carry whose every use was a window): bill the window pattern
        elif dus_root and full == out_b:
            continue
        else:
            total += full
    if dus_root and total == out_b:
        # pure in-place update fusion: output aliases the carry; traffic
        # is the window write, already included via accessed above
        pass
    if dus_root:
        # output buffer aliases the updated operand: don't bill the full
        # output write, only the updated windows (already in `accessed`)
        win = sum(_shape_bytes(body.symbols.get(
            _operands(b)[1] if len(_operands(b)) > 1 else b.name,
            b.out_shapes))
            for b in body.ops if b.opcode in _UPDATING)
        total = total - out_b + min(2 * win, out_b)
    return total


def _group_size(line: str) -> int:
    mg = _GROUPS_RE.search(line)
    if mg:
        return len(mg.group(1).split(","))
    mi = _IOTA_RE.search(line)
    if mi:
        return int(mi.group(2))
    return 1


def _trip_count(op: _Op, comps: dict) -> int:
    m = _TRIP_RE.search(op.line)
    if m:
        return int(m.group(1))
    mc = _COND_RE.search(op.line)
    if mc and mc.group(1) in comps:
        consts = []
        for o in comps[mc.group(1)].ops:
            if o.opcode in ("compare", "constant"):
                consts += [int(c) for c in _CONST_CMP_RE.findall(o.line)]
        if consts:
            return max(consts)
    return 1


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_result_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_link_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in other.coll_counts:
            self.coll_counts[k] += other.coll_counts[k] * mult
            self.coll_result_bytes[k] += other.coll_result_bytes[k] * mult
            self.coll_link_bytes[k] += other.coll_link_bytes[k] * mult

    def collective_stats(self) -> CollectiveStats:
        return CollectiveStats(
            counts={k: int(v) for k, v in self.coll_counts.items()},
            result_bytes=dict(self.coll_result_bytes),
            link_bytes=dict(self.coll_link_bytes))


def _comp_cost(comp: _Comp, comps: dict, memo: dict,
               in_fusion: bool = False) -> HloCost:
    key = (comp.name, in_fusion)
    if key in memo:
        return memo[key]
    cost = HloCost()
    for op in comp.ops:
        oc = op.opcode
        base = oc[:-6] if oc.endswith("-start") else oc
        if oc.endswith("-done"):
            continue
        if base in _COLLECTIVES:
            b = _shape_bytes(op.out_shapes)
            if oc.endswith("-start") and len(op.out_shapes) > 1:
                # start returns (operand alias, result): count result half
                b = b / 2
            b *= _wire_factor(op, comp, comps)    # bf16-on-target fix
            g = _group_size(op.line)
            cost.coll_counts[base] += 1
            cost.coll_result_bytes[base] += b
            cost.coll_link_bytes[base] += b * _ring_factor(base, g)
            cost.bytes += _shape_bytes(op.out_shapes)
            continue
        if oc == "fusion":
            m = _CALLS_RE.search(op.line)
            body_comp = comps.get(m.group(1)) if m else None
            if body_comp is not None:
                body = _comp_cost(body_comp, comps, memo, in_fusion=True)
                cost.flops += body.flops
            if not in_fusion:
                if body_comp is not None:
                    cost.bytes += _fusion_bytes(op, comp, body_comp)
                else:
                    cost.bytes += _operand_bytes(op, comp) + \
                        _shape_bytes(op.out_shapes)
            continue
        if oc == "while":
            mb, mc = _BODY_RE.search(op.line), _COND_RE.search(op.line)
            trip = _trip_count(op, comps)
            if mb and mb.group(1) in comps:
                cost.add(_comp_cost(comps[mb.group(1)], comps, memo), trip)
            if mc and mc.group(1) in comps:
                cost.add(_comp_cost(comps[mc.group(1)], comps, memo), trip)
            continue
        if oc in ("call", "map", "reduce", "reduce-window", "sort",
                  "scatter", "select-and-scatter", "conditional"):
            m = _TOAPPLY_RE.search(op.line) or _CALLS_RE.search(op.line)
            if m and m.group(1) in comps:
                sub = _comp_cost(comps[m.group(1)], comps, memo,
                                 in_fusion=True)
                # applied per output element for reduce/map/sort-ish ops
                mult = _elems(op.out_shapes) if oc != "call" else 1
                cost.flops += sub.flops * max(mult, 1)
            if not in_fusion:
                cost.bytes += _kernel_bytes(op, comp)
            continue
        if oc == "dot":
            cost.flops += _dot_flops(op, comp)
            if not in_fusion:
                cost.bytes += _kernel_bytes(op, comp)
            continue
        if oc == "convolution":
            # rare here; approximate as dot over kernel volume
            out_elems = _elems(op.out_shapes)
            cost.flops += 2.0 * out_elems
            if not in_fusion:
                cost.bytes += _kernel_bytes(op, comp)
            continue
        if oc in _FREE:
            if oc == "custom-call" and not in_fusion:
                cost.bytes += _kernel_bytes(op, comp)
            continue
        # generic elementwise / data movement
        cost.flops += _elems(op.out_shapes)
        if not in_fusion:
            cost.bytes += _kernel_bytes(op, comp)
    memo[key] = cost
    return cost


def analyze_hlo_text(hlo_text: str, entry: str | None = None) -> HloCost:
    """Trip-count-aware (flops, bytes, collectives) for an HLO module."""
    comps = parse_module(hlo_text)
    if not comps:
        return HloCost()
    if entry is None:
        # ENTRY computation: the one named like main, else largest
        entry_comp = None
        for name in comps:
            if name.startswith("main"):
                entry_comp = name
                break
        if entry_comp is None:
            entry_comp = max(comps, key=lambda n: len(comps[n].ops))
    else:
        entry_comp = entry
    # exclude computations reachable only as fusion bodies from double count
    memo: dict = {}
    return _comp_cost(comps[entry_comp], comps, memo)
