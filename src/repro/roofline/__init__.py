from .analysis import HBM_BW, ICI_BW, PEAK_FLOPS, Roofline, analyze  # noqa: F401
from .hlo import parse_collectives  # noqa: F401
