"""UNOMT application data + data-engineering pipeline (paper §4).

Synthetic stand-ins for the NCI60/gCSI drug-response data (the real data
is gated): three raw tables with the same *relational shape* the paper
describes — a drug-response table, two drug-feature sub-tables merged by
inner join, and an RNA-sequence table with duplicates — plus the exact
operator pipeline of paper Figures 8–11:

  read -> project (column filter) -> map (clean drug ids) -> dropna ->
  drop_duplicates -> inner joins -> isin filters -> distributed unique ->
  standard scaling -> to_tensor

The response is generated as a noisy function of drug/cell latent
features so the downstream drug-response network has real signal to
learn (examples/unomt_e2e.py).
"""
from __future__ import annotations

import numpy as np

from ..core import Table, local_ops as L, dist_ops as D
from ..core.context import HptmtContext


def gen_unomt_tables(*, n_response: int = 4096, n_drugs: int = 256,
                     n_cells: int = 128, n_drug_feat: int = 8,
                     n_rna_feat: int = 8, seed: int = 0):
    """Raw numpy columns for the three source tables (+ latents)."""
    rng = np.random.default_rng(seed)
    drug_lat = rng.normal(size=(n_drugs, n_drug_feat)).astype(np.float32)
    cell_lat = rng.normal(size=(n_cells, n_rna_feat)).astype(np.float32)
    w_d = rng.normal(size=(n_drug_feat,)).astype(np.float32)
    w_c = rng.normal(size=(n_rna_feat,)).astype(np.float32)

    did = rng.integers(0, n_drugs, n_response)
    cid = rng.integers(0, n_cells, n_response)
    conc = rng.uniform(-3, 0, n_response).astype(np.float32)
    resp = (drug_lat[did] @ w_d + cell_lat[cid] @ w_c
            + 0.5 * conc + 0.05 * rng.normal(size=n_response)) \
        .astype(np.float32)
    # the paper's raw table has extra columns (filtered by Project), drug
    # ids needing a cleanup map (we encode "symbols" as an offset), and
    # some null responses (dropna).
    response = {
        "drug_id_raw": (did + 1_000_000).astype(np.int32),
        "cell_id": cid.astype(np.int32),
        "concentration": conc,
        "response": np.where(rng.random(n_response) < 0.02, np.nan,
                             resp).astype(np.float32),
        "study": rng.integers(0, 6, n_response).astype(np.int32),
        "junk_a": rng.normal(size=n_response).astype(np.float32),
        "junk_b": rng.integers(0, 9, n_response).astype(np.int32),
    }
    # drug features arrive as two sub-tables merged on drug id
    descriptors = {"drug_id": np.arange(n_drugs, dtype=np.int32)}
    for j in range(n_drug_feat // 2):
        descriptors[f"desc{j}"] = drug_lat[:, j]
    fingerprints = {"drug_id": np.arange(n_drugs, dtype=np.int32)}
    for j in range(n_drug_feat // 2, n_drug_feat):
        fingerprints[f"fp{j}"] = drug_lat[:, j]
    # rna sequences with duplicate records (paper: drop duplicate op)
    dup = rng.integers(0, n_cells, n_cells // 4)
    rna_ids = np.concatenate([np.arange(n_cells), dup]).astype(np.int32)
    rng.shuffle(rna_ids)
    rna = {"cell_id": rna_ids}
    for j in range(n_rna_feat):
        rna[f"rna{j}"] = cell_lat[rna_ids, j]
    return {"response": response, "descriptors": descriptors,
            "fingerprints": fingerprints, "rna": rna}


def drug_feature_cols(n_drug_feat: int = 8):
    return [f"desc{j}" for j in range(n_drug_feat // 2)] + \
        [f"fp{j}" for j in range(n_drug_feat // 2, n_drug_feat)]


def rna_cols(n_rna_feat: int = 8):
    return [f"rna{j}" for j in range(n_rna_feat)]


def _clean_response(resp: Table, ctx: HptmtContext | None = None) -> Table:
    """Fig. 8: column filter -> map (clean drug id) -> dropna -> scale.

    With ``ctx`` the scaling uses exact *global* moments (psum) so results
    are parallelism-invariant; without it, single-partition moments."""
    t = L.project(resp, ["drug_id_raw", "cell_id", "concentration",
                         "response"])
    t = t.map_column("drug_id_raw", lambda c: c - 1_000_000, out="drug_id")
    t = L.project(t, ["drug_id", "cell_id", "concentration", "response"])
    t = L.dropna(t, ["response"])
    if ctx is None:
        t = L.standard_scale(t, ["concentration"])
    else:
        t = D.dist_standard_scale(ctx, t, ["concentration"])
    return t


def unomt_local_pipeline(resp: Table, desc: Table, fp: Table, rna: Table,
                         *, n_drug_feat: int = 8, n_rna_feat: int = 8,
                         out_capacity: int | None = None,
                         semi_impl: str | None = None) -> Table:
    """Single-partition version of Figures 8–11 (jittable).

    ``semi_impl`` selects the Fig.-11 membership backend ('sortmerge' |
    'hash', default ``kernel_backend.semi_impl()``)."""
    t = _clean_response(resp)
    drug = L.join(desc, fp, left_on=["drug_id"],
                  out_capacity=desc.capacity)              # Fig. 9
    rna_u = L.drop_duplicates(rna, ["cell_id"])            # Fig. 10
    rna_u = L.standard_scale(rna_u, rna_cols(n_rna_feat))
    # Fig. 11: keep response rows whose drug/cell exist in both sides
    keep = L.isin(t, "drug_id", drug, "drug_id", impl=semi_impl) & \
        L.isin(t, "cell_id", rna_u, "cell_id", impl=semi_impl)
    t = L.select(t, keep)
    t = L.join(t, drug, left_on=["drug_id"],
               out_capacity=out_capacity or t.capacity)
    t = L.join(t, rna_u, left_on=["cell_id"],
               out_capacity=out_capacity or t.capacity)
    return t


def unomt_dist_pipeline(ctx: HptmtContext, resp: Table, desc: Table,
                        fp: Table, rna: Table, *, n_drug_feat: int = 8,
                        n_rna_feat: int = 8, overcommit: float = 4.0,
                        semi_impl: str | None = None):
    """Distributed version: local cleanup is pleasingly parallel (paper
    §4.3); joins/unique are the distributed operators.  Returns
    (features table, total dropped rows) — run under DistributedPipeline.

    ``semi_impl`` selects the membership backend for the Fig.-11 filter
    ('sortmerge' | 'hash', default ``kernel_backend.semi_impl()``).
    """
    t = _clean_response(resp, ctx)
    drug, d1 = D.dist_join(ctx, desc, fp, left_on=["drug_id"],
                           overcommit=overcommit)
    rna_u, d2 = D.dist_unique(ctx, rna, ["cell_id"],
                              overcommit=overcommit)
    rna_u = D.dist_standard_scale(ctx, rna_u, rna_cols(n_rna_feat))
    # membership against the *global* id sets (broadcast the small keys)
    drug_ids = D.all_gather_table(ctx, L.project(drug, ["drug_id"]))
    cell_ids = D.all_gather_table(ctx, L.project(rna_u, ["cell_id"]))
    keep = L.isin(t, "drug_id", drug_ids, "drug_id", impl=semi_impl) & \
        L.isin(t, "cell_id", cell_ids, "cell_id", impl=semi_impl)
    t = L.select(t, keep)
    t, d3 = D.dist_join(ctx, t, drug, left_on=["drug_id"],
                        overcommit=overcommit)
    t, d4 = D.dist_join(ctx, t, rna_u, left_on=["cell_id"],
                        overcommit=overcommit)
    # rebalance after skewed joins (straggler mitigation)
    t, d5 = D.dist_repartition(ctx, t)
    return t, d1 + d2 + d3 + d4 + d5


def feature_label_arrays(t: Table, *, n_drug_feat: int = 8,
                         n_rna_feat: int = 8):
    """Stage 3 (paper Listing 3): Table -> (X, y) tensors."""
    feats = ["concentration"] + drug_feature_cols(n_drug_feat) \
        + rna_cols(n_rna_feat)
    X = t.to_tensor(feats)
    y = t.to_tensor(["response"])[:, 0]
    return X, y, t.valid_mask
