"""Deterministic synthetic LM data.

Sequences follow per-sequence affine recurrences ``t_{i+1} = (a*t_i + c)
mod V`` with a sprinkle of noise — fully learnable structure so the
training examples show real loss curves, and *step-addressable* (batch k
is a pure function of (seed, k)) so restart-after-failure resumes the
exact data order (runtime.trainer.run_with_restarts)."""
from __future__ import annotations

import numpy as np


def lm_batch_at(step: int, *, vocab: int, batch: int, seq: int,
                seed: int = 0, noise: float = 0.05):
    rng = np.random.default_rng(seed * 1_000_003 + step)
    a = rng.integers(1, 8, size=(batch, 1))
    c = rng.integers(0, vocab, size=(batch, 1))
    t0 = rng.integers(0, vocab, size=(batch, 1))
    idx = np.arange(seq + 1)
    toks = t0
    seqs = [t0]
    for _ in range(seq):
        toks = (toks * a + c) % vocab
        seqs.append(toks)
    toks = np.concatenate(seqs, axis=1)              # (B, S+1)
    flip = rng.random(toks.shape) < noise
    toks = np.where(flip, rng.integers(0, vocab, toks.shape), toks)
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def lm_batches(start_step: int, **kw):
    step = start_step
    while True:
        yield lm_batch_at(step, **kw)
        step += 1
