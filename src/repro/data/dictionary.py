"""Dictionary encoding for string columns (host-side).

TPUs have no string type; Arrow's standard answer is dictionary encoding
— string columns become int32 ids + a host-side vocabulary.  This is the
boundary where the HPTMT table engine meets raw data (DESIGN.md §2).
"""
from __future__ import annotations

import numpy as np


class Dictionary:
    def __init__(self):
        self.vocab: dict[str, int] = {}
        self.items: list[str] = []

    def encode(self, values) -> np.ndarray:
        out = np.empty(len(values), np.int32)
        for i, v in enumerate(values):
            v = str(v)
            idx = self.vocab.get(v)
            if idx is None:
                idx = len(self.items)
                self.vocab[v] = idx
                self.items.append(v)
            out[i] = idx
        return out

    def decode(self, ids) -> list[str]:
        return [self.items[int(i)] for i in ids]
