from . import dictionary, synthetic, unomt  # noqa: F401
