"""Local (single-partition) HPTMT table operators.

These are the paper's Table-2 operators — Select, Project, Union,
Difference, Intersect, Join, OrderBy, Aggregate, GroupBy (+ the UNOMT
helpers: unique/drop_duplicates, isin, dropna/fillna, map, astype) —
implemented as pure, jittable, *static-shape* JAX functions over
:class:`repro.core.table.Table`.

TPU adaptation notes (see DESIGN.md §2):
* every op is mask-aware: rows ``>= nvalid`` are padding;
* local join has two backends selected by ``impl`` (default via
  ``kernel_backend.join_impl()`` / ``REPRO_JOIN_IMPL``):

  - ``"sortmerge"`` — binary search over sorted keys; exact for any key
    distribution, O((L+R) log) sorts per call;
  - ``"hash"`` — bucketed build+probe on the ``kernels/hash_join`` Pallas
    kernel; no sorts, but static per-bucket capacities (overflow is
    counted, see the kernel package README) — the paper's hash-local-join
    fast path for shuffled (10%-unique-key style) workloads;

* multi-column keys are exact in both backends: lexicographic binary
  search (:func:`lex_searchsorted`) / full key-bit equality — no hash
  collisions, no int64 packing.
"""
from __future__ import annotations

from functools import partial
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

from ..kernels.hash_join import default_hash_join_sizes, hash_join_plan
from .kernel_backend import join_impl as _default_join_impl
from .kernel_backend import table_kernel_impl as _default_kernel_impl
from .table import Table, isnull_values, null_like

# --------------------------------------------------------------------------
# small helpers
# --------------------------------------------------------------------------


def _sentinel_max(col: jax.Array) -> jax.Array:
    if jnp.issubdtype(col.dtype, jnp.floating):
        return jnp.asarray(jnp.inf, col.dtype)
    return jnp.asarray(jnp.iinfo(col.dtype).max, col.dtype)


def compact(table: Table, keep: jax.Array) -> Table:
    """Move rows where ``keep`` holds to the front (stable); drop the rest."""
    keep = keep & table.valid_mask
    perm = jnp.argsort(jnp.logical_not(keep), stable=True)
    return table.gather_rows(perm, jnp.sum(keep, dtype=jnp.int32))


# --------------------------------------------------------------------------
# Select / Project / head / take / concat
# --------------------------------------------------------------------------


def select(table: Table, mask: jax.Array) -> Table:
    """Paper's Select: keep rows where ``mask`` (bool (capacity,)) holds."""
    return compact(table, mask)


def project(table: Table, names: Sequence[str]) -> Table:
    """Paper's Project: keep a subset of columns."""
    return Table(columns={n: table.columns[n] for n in names},
                 nvalid=table.nvalid)


def head(table: Table, n) -> Table:
    return table.with_nvalid(jnp.minimum(table.nvalid, jnp.int32(n)))


def take(table: Table, idx: jax.Array, count) -> Table:
    return table.gather_rows(idx, count)


def concat(a: Table, b: Table) -> Table:
    """Union-all of two same-schema tables (capacity = sum of capacities)."""
    if set(a.names) != set(b.names):
        raise ValueError(f"schema mismatch: {a.names} vs {b.names}")
    cap_a, cap_b = a.capacity, b.capacity
    out_cap = cap_a + cap_b
    i = jnp.arange(out_cap, dtype=jnp.int32)
    from_a = i < a.nvalid
    ia = jnp.clip(i, 0, cap_a - 1)
    ib = jnp.clip(i - a.nvalid, 0, cap_b - 1)
    cols = {}
    for n in a.names:
        ca, cb = a.columns[n], b.columns[n].astype(a.columns[n].dtype)
        cols[n] = jnp.where(from_a, ca[ia], cb[ib])
    return Table(columns=cols, nvalid=a.nvalid + b.nvalid)


# --------------------------------------------------------------------------
# OrderBy (sort_values)
# --------------------------------------------------------------------------


def _sort_key(col: jax.Array, ascending: bool) -> jax.Array:
    if ascending:
        return col
    if jnp.issubdtype(col.dtype, jnp.floating):
        return -col
    return ~col  # two's-complement: exact order reversal, no overflow


def sort_values(table: Table, by: Sequence[str],
                ascending: bool | Sequence[bool] = True) -> Table:
    """Paper's OrderBy: stable multi-key sort; padding rows stay at the end."""
    by = list(by)
    if isinstance(ascending, bool):
        ascending = [ascending] * len(by)
    invalid = (~table.valid_mask).astype(jnp.int32)
    keys = [_sort_key(table.columns[k], a) for k, a in zip(by, ascending)]
    iota = jnp.arange(table.capacity, dtype=jnp.int32)
    out = jax.lax.sort((invalid, *keys, iota), num_keys=1 + len(keys),
                       is_stable=True)
    perm = out[-1]
    return table.gather_rows(perm, table.nvalid)


# --------------------------------------------------------------------------
# Lexicographic vectorized binary search (exact, multi-key, static shape)
# --------------------------------------------------------------------------


def _tuple_less(a: tuple, b: tuple) -> jax.Array:
    """a < b lexicographically (element-wise over vectors)."""
    res = jnp.zeros(a[0].shape, bool)
    eq = jnp.ones(a[0].shape, bool)
    for x, y in zip(a, b):
        res = res | (eq & (x < y))
        eq = eq & (x == y)
    return res


def lex_searchsorted(sorted_keys: tuple, query_keys: tuple,
                     side: str = "left") -> jax.Array:
    """``searchsorted`` over a tuple of parallel sorted key columns.

    ``sorted_keys[i]`` all share shape ``(n,)`` and are lexicographically
    sorted; ``query_keys[i]`` share shape ``(m,)``.  Returns int32 ``(m,)``
    insertion points.  Exact (comparison-based), O(m log n).
    """
    n = sorted_keys[0].shape[0]
    m = query_keys[0].shape[0]
    lo = jnp.zeros((m,), jnp.int32)
    hi = jnp.full((m,), n, jnp.int32)
    iters = max(1, int(n - 1).bit_length() + 1) if n > 0 else 1

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        midc = jnp.clip(mid, 0, n - 1)
        at_mid = tuple(k[midc] for k in sorted_keys)
        if side == "left":
            go_right = _tuple_less(at_mid, query_keys)        # k[mid] < q
        else:
            go_right = ~_tuple_less(query_keys, at_mid)       # k[mid] <= q
        go_right = go_right & (mid < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo


def _sorted_keys_with_sentinel(table: Table, by: Sequence[str]):
    """Sort table by ``by``; overwrite padding keys with +max sentinels so the
    full-capacity key arrays are globally sorted."""
    ts = sort_values(table, by)
    valid = ts.valid_mask
    keys = []
    for k in by:
        col = ts.columns[k]
        keys.append(jnp.where(valid, col, _sentinel_max(col)))
    return ts, tuple(keys)


# --------------------------------------------------------------------------
# Unique / drop_duplicates
# --------------------------------------------------------------------------


def drop_duplicates(table: Table, subset: Sequence[str] | None = None) -> Table:
    """Keep the first occurrence of each distinct key (paper: Unique)."""
    subset = list(subset) if subset is not None else list(table.names)
    ts = sort_values(table, subset)
    valid = ts.valid_mask
    neq_prev = jnp.zeros(ts.capacity, bool)
    for k in subset:
        col = ts.columns[k]
        prev = jnp.roll(col, 1)
        neq_prev = neq_prev | (col != prev)
    first = jnp.arange(ts.capacity) == 0
    boundary = (first | neq_prev) & valid
    return compact(ts, boundary)


unique = drop_duplicates


# --------------------------------------------------------------------------
# GroupBy + Aggregate
# --------------------------------------------------------------------------

_AGGS = ("sum", "count", "mean", "min", "max")


def groupby_aggregate(table: Table, by: Sequence[str],
                      aggs: Mapping[str, Sequence[str] | str]) -> Table:
    """Paper's GroupBy followed by Aggregate.

    ``aggs`` maps value-column name -> aggregation(s) in
    {sum,count,mean,min,max}.  Output columns are named ``{col}_{agg}``;
    one row per distinct key, capacity preserved.
    """
    by = list(by)
    ts = sort_values(table, by)
    valid = ts.valid_mask
    cap = ts.capacity
    neq_prev = jnp.zeros(cap, bool)
    for k in by:
        col = ts.columns[k]
        neq_prev = neq_prev | (col != jnp.roll(col, 1))
    boundary = ((jnp.arange(cap) == 0) | neq_prev) & valid
    ngroups = jnp.sum(boundary, dtype=jnp.int32)
    seg = jnp.cumsum(boundary.astype(jnp.int32)) - 1          # 0-based
    # padding rows -> trash segment (cap-1 is free whenever padding exists)
    seg = jnp.where(valid, seg, cap - 1)

    out_cols: dict[str, jax.Array] = {}
    for k in by:
        out_cols[k] = ts.columns[k]
    counts = jax.ops.segment_sum(valid.astype(jnp.float32), seg,
                                 num_segments=cap)
    for col_name, ops in aggs.items():
        if isinstance(ops, str):
            ops = [ops]
        col = ts.columns[col_name]
        fcol = col.astype(jnp.float32)
        for op in ops:
            if op not in _AGGS:
                raise ValueError(f"unknown aggregation {op!r}")
            if op == "sum":
                v = jax.ops.segment_sum(jnp.where(valid, fcol, 0.0), seg, cap)
            elif op == "count":
                v = counts
            elif op == "mean":
                s = jax.ops.segment_sum(jnp.where(valid, fcol, 0.0), seg, cap)
                v = s / jnp.maximum(counts, 1.0)
            elif op == "min":
                v = jax.ops.segment_min(
                    jnp.where(valid, fcol, jnp.inf), seg, cap)
            elif op == "max":
                v = jax.ops.segment_max(
                    jnp.where(valid, fcol, -jnp.inf), seg, cap)
            out_cols[f"{col_name}_{op}"] = v

    # segment g's result sits at index g; boundary row g sits at the g-th
    # boundary position — compacting boundary rows aligns keys with index g.
    key_tbl = compact(Table(columns={k: out_cols[k] for k in by},
                            nvalid=ts.nvalid), boundary)
    cols = dict(key_tbl.columns)
    for name, v in out_cols.items():
        if name not in by:
            cols[name] = v  # already indexed by group id
    return Table(columns=cols, nvalid=ngroups)


def aggregate(table: Table, col: str, op: str) -> jax.Array:
    """Whole-column masked reduction -> scalar (paper's Aggregate)."""
    valid = table.valid_mask
    x = table.columns[col].astype(jnp.float32)
    n = jnp.maximum(table.nvalid.astype(jnp.float32), 1.0)
    if op == "sum":
        return jnp.sum(jnp.where(valid, x, 0.0))
    if op == "count":
        return table.nvalid.astype(jnp.float32)
    if op == "mean":
        return jnp.sum(jnp.where(valid, x, 0.0)) / n
    if op == "min":
        return jnp.min(jnp.where(valid, x, jnp.inf))
    if op == "max":
        return jnp.max(jnp.where(valid, x, -jnp.inf))
    if op == "std":
        m = jnp.sum(jnp.where(valid, x, 0.0)) / n
        v = jnp.sum(jnp.where(valid, (x - m) ** 2, 0.0)) / n
        return jnp.sqrt(v)
    raise ValueError(f"unknown aggregation {op!r}")


# --------------------------------------------------------------------------
# Join (pluggable backend: sort-merge / bucketed hash; static output
# capacity either way)
# --------------------------------------------------------------------------


def join(left: Table, right: Table, *,
         left_on: Sequence[str], right_on: Sequence[str] | None = None,
         how: str = "inner", out_capacity: int | None = None,
         suffix: str = "_r", return_overflow: bool = False,
         impl: str | None = None, num_buckets: int | None = None,
         bucket_capacity: int | None = None,
         probe_capacity: int | None = None,
         kernel_impl: str | None = None):
    """Paper's Join: inner/left join with static output capacity.

    ``impl`` picks the backend (default ``kernel_backend.join_impl()``):
    ``"sortmerge"`` or ``"hash"``.  Both emit *identical* output — same
    rows, same order: left-row-major, and within a left row its matches in
    the right table's original row order — so they are drop-in
    interchangeable (conformance: tests/test_join_backends.py).

    ``out_capacity`` defaults to ``left.capacity``; overflowing output
    rows are dropped and counted (``return_overflow=True`` returns the
    count).  The hash backend adds ``num_buckets`` / ``bucket_capacity`` /
    ``probe_capacity`` static sizing (auto-sized from the table capacities
    when omitted; rows overflowing a bucket slab are dropped and counted
    into the same overflow metric) and ``kernel_impl``
    (ref | pallas | pallas_interpret) for the probe kernel.
    """
    if how not in ("inner", "left"):
        raise ValueError("how must be 'inner' or 'left'")
    impl = impl or _default_join_impl()
    left_on = list(left_on)
    right_on = list(right_on) if right_on is not None else left_on
    out_cap = out_capacity or left.capacity
    if impl == "sortmerge":
        return _sortmerge_join(left, right, left_on, right_on, how, out_cap,
                               suffix, return_overflow)
    if impl == "hash":
        return _hash_join(left, right, left_on, right_on, how, out_cap,
                          suffix, return_overflow, num_buckets,
                          bucket_capacity, probe_capacity, kernel_impl)
    raise ValueError(f"unknown join impl {impl!r} "
                     "(expected 'sortmerge' or 'hash')")


def _emit_layout(match_counts: jax.Array, lvalid: jax.Array, how: str):
    """(inclusive cumsum, exclusive offsets, total) of per-left-row emit
    counts — the left-row-major layout shared by both join backends (left
    join emits 1 slot for each ``lvalid`` row with no matches)."""
    if how == "left":
        emit = jnp.where(lvalid & (match_counts == 0), 1, match_counts)
    else:
        emit = match_counts
    cum = jnp.cumsum(emit)
    offs = cum - emit
    total = cum[-1] if emit.shape[0] > 0 else jnp.int32(0)
    return cum, offs, total


def _sortmerge_join(left: Table, right: Table, left_on, right_on, how,
                    out_cap, suffix, return_overflow):
    """Sort-merge backend: the right table is sorted by its keys; each left
    row binary-searches its match range ``[lo, hi)``; output slot ``j`` is
    mapped back to its (left row, match offset) pair with a second
    searchsorted — fully vectorized, no dynamic shapes."""
    rs, rkeys = _sorted_keys_with_sentinel(right, right_on)
    qkeys = tuple(left.columns[k].astype(rs.columns[rk].dtype)
                  for k, rk in zip(left_on, right_on))
    lo = lex_searchsorted(rkeys, qkeys, side="left")
    hi = lex_searchsorted(rkeys, qkeys, side="right")
    lo = jnp.minimum(lo, right.nvalid)
    hi = jnp.minimum(hi, right.nvalid)
    lvalid = left.valid_mask
    match_counts = jnp.where(lvalid, hi - lo, 0)
    cum, offs, total = _emit_layout(match_counts, lvalid, how)

    j = jnp.arange(out_cap, dtype=jnp.int32)
    lrow = jnp.searchsorted(cum, j, side="right").astype(jnp.int32)
    lrow = jnp.clip(lrow, 0, left.capacity - 1)
    within = j - offs[lrow]
    matched = within < match_counts[lrow]
    rrow = jnp.clip(lo[lrow] + within, 0, max(right.capacity - 1, 0))

    cols: dict[str, jax.Array] = {}
    for n in left.names:
        cols[n] = left.columns[n][lrow]
    drop_keys = set(right_on) if left_on == right_on else set()
    for n in rs.names:
        if n in drop_keys:
            continue
        name = n + suffix if n in cols else n
        v = rs.columns[n][rrow]
        if how == "left":
            v = jnp.where(matched, v, null_like(v))
        cols[name] = v
    out = Table(columns=cols, nvalid=jnp.minimum(total, out_cap))
    if return_overflow:
        return out, jnp.maximum(total - out_cap, 0)
    return out


def _hash_join(left: Table, right: Table, left_on, right_on, how,
               out_cap, suffix, return_overflow, num_buckets,
               bucket_capacity, probe_capacity, kernel_impl):
    """Hash backend: bucketed build+probe (kernels/hash_join) instead of
    two sorts.  The plan yields per-left-row match counts plus per
    (probe slot, chain slot) match ranks; matched pairs are scattered into
    their output slots (offset of the left row + rank of the match), which
    reproduces the sort-merge output ordering exactly because chain order
    is original-right-row order."""
    B, C, Lc = default_hash_join_sizes(left.capacity, right.capacity,
                                       num_buckets)
    C = bucket_capacity or C
    Lc = probe_capacity or Lc
    qkeys = tuple(left.columns[k].astype(right.columns[rk].dtype)
                  for k, rk in zip(left_on, right_on))
    rkeys = tuple(right.columns[rk] for rk in right_on)
    plan = hash_join_plan(qkeys, left.valid_mask, rkeys, right.valid_mask,
                          num_buckets=B, bucket_capacity=C,
                          probe_capacity=Lc,
                          impl=kernel_impl or _default_kernel_impl())

    # a probe-dropped left row's match status is unknown: it is excluded
    # from emission entirely (counted in probe_dropped), never emitted as
    # a fake unmatched row — "overflow rows are dropped and counted"
    lvalid = left.valid_mask & plan.probed
    mc = plan.match_counts
    cum, offs, total = _emit_layout(mc, lvalid, how)

    # scatter matched pairs: slot = offs[left row] + within-row match rank
    slot = offs[plan.probe_row][:, :, None] + plan.rank      # (B, Lc, C)
    keep = (plan.rank >= 0) & (slot < out_cap)
    flat = jnp.where(keep, slot, out_cap).reshape(-1)
    lrow_pair = jnp.broadcast_to(plan.probe_row[:, :, None], keep.shape)
    rrow_pair = jnp.broadcast_to(plan.build_row[:, None, :], keep.shape)
    buf_l = jnp.zeros((out_cap + 1,), jnp.int32) \
        .at[flat].set(lrow_pair.reshape(-1))
    buf_r = jnp.zeros((out_cap + 1,), jnp.int32) \
        .at[flat].set(rrow_pair.reshape(-1))
    buf_m = jnp.zeros((out_cap + 1,), bool).at[flat].set(keep.reshape(-1))
    if how == "left":
        un = lvalid & (mc == 0)
        flat_u = jnp.where(un & (offs < out_cap), offs, out_cap)
        buf_l = buf_l.at[flat_u].set(
            jnp.arange(left.capacity, dtype=jnp.int32))
    out_lrow = buf_l[:out_cap]
    out_rrow = buf_r[:out_cap]
    matched = buf_m[:out_cap]

    cols: dict[str, jax.Array] = {}
    for n in left.names:
        cols[n] = left.columns[n][out_lrow]
    drop_keys = set(right_on) if left_on == right_on else set()
    for n in right.names:
        if n in drop_keys:
            continue
        name = n + suffix if n in cols else n
        v = right.columns[n][out_rrow]
        if how == "left":
            v = jnp.where(matched, v, null_like(v))
        cols[name] = v
    out = Table(columns=cols, nvalid=jnp.minimum(total, out_cap))
    if return_overflow:
        overflow = (jnp.maximum(total - out_cap, 0)
                    + plan.build_dropped + plan.probe_dropped)
        return out, overflow
    return out


def cartesian_product(left: Table, right: Table, out_capacity: int,
                      suffix: str = "_r") -> Table:
    """Paper's Cartesian Product (static output capacity)."""
    n2 = jnp.maximum(right.nvalid, 1)
    j = jnp.arange(out_capacity, dtype=jnp.int32)
    lrow = jnp.clip(j // n2, 0, max(left.capacity - 1, 0))
    rrow = jnp.clip(j % n2, 0, max(right.capacity - 1, 0))
    total = left.nvalid * right.nvalid
    cols = {n: left.columns[n][lrow] for n in left.names}
    for n in right.names:
        name = n + suffix if n in cols else n
        cols[name] = right.columns[n][rrow]
    return Table(columns=cols, nvalid=jnp.minimum(total, out_capacity))


# --------------------------------------------------------------------------
# Membership + set operators
# --------------------------------------------------------------------------


def isin(table: Table, col: str, values: Table, values_col: str) -> jax.Array:
    """Bool mask: table[col] present among valid values[values_col]."""
    vs, vkeys = _sorted_keys_with_sentinel(values, [values_col])
    q = (table.columns[col].astype(vs.columns[values_col].dtype),)
    lo = lex_searchsorted(vkeys, q, side="left")
    hi = lex_searchsorted(vkeys, q, side="right")
    lo = jnp.minimum(lo, values.nvalid)
    hi = jnp.minimum(hi, values.nvalid)
    return (hi > lo) & table.valid_mask


def _semi_mask(left: Table, right: Table, on: Sequence[str]) -> jax.Array:
    rs, rkeys = _sorted_keys_with_sentinel(right, list(on))
    q = tuple(left.columns[k].astype(rs.columns[k].dtype) for k in on)
    lo = lex_searchsorted(rkeys, q, side="left")
    hi = lex_searchsorted(rkeys, q, side="right")
    lo = jnp.minimum(lo, right.nvalid)
    hi = jnp.minimum(hi, right.nvalid)
    return (hi > lo) & left.valid_mask


def intersect(a: Table, b: Table, on: Sequence[str] | None = None) -> Table:
    """Paper's Intersect: distinct rows of ``a`` present in ``b``."""
    on = list(on) if on is not None else list(a.names)
    return drop_duplicates(compact(a, _semi_mask(a, b, on)), on)


def difference(a: Table, b: Table, on: Sequence[str] | None = None) -> Table:
    """Paper's Difference: rows of ``a`` with no match in ``b``."""
    on = list(on) if on is not None else list(a.names)
    return compact(a, a.valid_mask & ~_semi_mask(a, b, on))


def union(a: Table, b: Table) -> Table:
    """Paper's Union: concat + dedup."""
    return drop_duplicates(concat(a, b))


# --------------------------------------------------------------------------
# Null handling (UNOMT ops: isnull / notnull / dropna / fillna)
# --------------------------------------------------------------------------


def isnull(table: Table, col: str) -> jax.Array:
    return isnull_values(table.columns[col]) & table.valid_mask


def dropna(table: Table, subset: Sequence[str] | None = None) -> Table:
    subset = list(subset) if subset is not None else list(table.names)
    bad = jnp.zeros(table.capacity, bool)
    for k in subset:
        bad = bad | isnull_values(table.columns[k])
    return compact(table, ~bad)


def fillna(table: Table, values: Mapping[str, float]) -> Table:
    cols = dict(table.columns)
    for k, v in values.items():
        col = cols[k]
        cols[k] = jnp.where(isnull_values(col),
                            jnp.asarray(v, col.dtype), col)
    return Table(columns=cols, nvalid=table.nvalid)


# --------------------------------------------------------------------------
# Column-wise math used by the UNOMT pipeline (scikit-learn-style scaling)
# --------------------------------------------------------------------------


def standard_scale(table: Table, cols: Sequence[str]) -> Table:
    """(x - mean) / std per column over valid rows (sklearn StandardScaler)."""
    out = dict(table.columns)
    valid = table.valid_mask
    n = jnp.maximum(table.nvalid.astype(jnp.float32), 1.0)
    for k in cols:
        x = out[k].astype(jnp.float32)
        m = jnp.sum(jnp.where(valid, x, 0.0)) / n
        v = jnp.sum(jnp.where(valid, (x - m) ** 2, 0.0)) / n
        out[k] = (x - m) / jnp.sqrt(v + 1e-12)
    return Table(columns=out, nvalid=table.nvalid)
