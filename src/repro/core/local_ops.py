"""Local (single-partition) HPTMT table operators.

These are the paper's Table-2 operators — Select, Project, Union,
Difference, Intersect, Join, OrderBy, Aggregate, GroupBy (+ the UNOMT
helpers: unique/drop_duplicates, isin, dropna/fillna, map, astype) —
implemented as pure, jittable, *static-shape* JAX functions over
:class:`repro.core.table.Table`.

TPU adaptation notes (see DESIGN.md §2):
* every op is mask-aware: rows ``>= nvalid`` are padding;
* local join has two backends selected by ``impl`` (default via
  ``kernel_backend.join_impl()`` / ``REPRO_JOIN_IMPL``):

  - ``"sortmerge"`` — binary search over sorted keys; exact for any key
    distribution, O((L+R) log) sorts per call;
  - ``"hash"`` — bucketed build+probe on the ``kernels/hash_join`` Pallas
    kernel; no sorts, but static per-bucket capacities (overflow is
    counted, see the kernel package README) — the paper's hash-local-join
    fast path for shuffled (10%-unique-key style) workloads;

* the aggregation family (groupby_aggregate, drop_duplicates) has the
  same two backends via ``impl`` (default ``kernel_backend.groupby_impl()``
  / ``REPRO_GROUPBY_IMPL``):

  - ``"sort"`` — lexicographic tuple sort + segment reductions;
  - ``"hash"`` — bucketed hash-accumulate on the ``kernels/hash_groupby``
    Pallas kernel: sum/count/mean/min/max per distinct key in one pass,
    **no sort primitive anywhere on the path** (canonical key order is
    recovered with a multi-pass radix rank over the distinct keys,
    ``kernels/radix_sort``);

* OrderBy (sort_values) itself has two backends via ``impl`` (default
  ``kernel_backend.sort_impl()`` / ``REPRO_SORT_IMPL``):

  - ``"xla"`` — one stable ``jax.lax.sort`` over (validity, keys, iota);
  - ``"radix"`` — the ``kernels/radix_sort`` multi-pass LSD engine: a
    chain of stable counting-sort digit passes, **no sort primitive in
    the jaxpr** — bit-identical rows/order/dtypes either way
    (conformance: tests/test_sort_backends.py);

  ``compact()``/``select()`` (and the shuffle's receive side in
  dist_ops) always take the engine's 1-bit fast path — a single
  counting pass that is bit-identical to the stable boolean argsort it
  replaces, so row compaction never sorts;

  both emit *canonicalized* output — one row per distinct key, sorted by
  key, counts int32 — so they are bit-identical and drop-in
  interchangeable (conformance: tests/test_groupby_backends.py; float
  ``sum``/``mean`` are bit-identical whenever addition is exact, e.g.
  integer-valued data, and agree to rounding otherwise);

* the membership family (isin, semi_mask, intersect, difference) has two
  backends via ``impl`` (default ``kernel_backend.semi_impl()`` /
  ``REPRO_SEMI_IMPL``):

  - ``"sortmerge"`` — sort the right key set, binary-search each probe;
  - ``"hash"`` — bucketed build+probe membership on ``kernels/hash_semi``:
    one boolean per probe row, no join materialization, **no sort
    primitive anywhere on the path**;

  both compare key pairs in their *promoted* common dtype (as do the
  join backends), so mixed-dtype probes cannot collide distinct keys —
  bit-identical masks either way (conformance:
  tests/test_setop_backends.py);

* multi-column keys are exact in both backends: lexicographic binary
  search (:func:`lex_searchsorted`) / full key-bit equality — no hash
  collisions, no int64 packing.
"""
from __future__ import annotations

from functools import partial
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

from ..kernels import bucketing
from ..kernels.hash_groupby import (default_hash_groupby_sizes,
                                    hash_groupby_plan)
from ..kernels.hash_join import default_hash_join_sizes, hash_join_plan
from ..kernels.hash_semi import default_hash_semi_sizes, hash_semi_plan
from ..kernels.radix_sort import (radix_permutation, radix_rank,
                                  stable_partition_perm)
from .kernel_backend import groupby_impl as _default_groupby_impl
from .kernel_backend import join_impl as _default_join_impl
from .kernel_backend import semi_impl as _default_semi_impl
from .kernel_backend import sort_impl as _default_sort_impl
from .kernel_backend import table_kernel_impl as _default_kernel_impl
from .table import Table, isnull_values, null_like

# --------------------------------------------------------------------------
# small helpers
# --------------------------------------------------------------------------


def _sentinel_max(col: jax.Array) -> jax.Array:
    if jnp.issubdtype(col.dtype, jnp.floating):
        return jnp.asarray(jnp.inf, col.dtype)
    return jnp.asarray(jnp.iinfo(col.dtype).max, col.dtype)


def compact(table: Table, keep: jax.Array,
            kernel_impl: str | None = None) -> Table:
    """Move rows where ``keep`` holds to the front (stable); drop the rest.

    Runs the radix engine's 1-bit fast path (one stable counting pass,
    ``kernels/radix_sort``) — bit-identical to the boolean
    ``argsort(~keep, stable=True)`` it replaces, with no sort primitive.
    """
    keep = keep & table.valid_mask
    perm = stable_partition_perm(keep,
                                 impl=kernel_impl or _default_kernel_impl())
    return table.gather_rows(perm, jnp.sum(keep, dtype=jnp.int32))


# --------------------------------------------------------------------------
# Select / Project / head / take / concat
# --------------------------------------------------------------------------


def select(table: Table, mask: jax.Array) -> Table:
    """Paper's Select: keep rows where ``mask`` (bool (capacity,)) holds."""
    return compact(table, mask)


def project(table: Table, names: Sequence[str]) -> Table:
    """Paper's Project: keep a subset of columns."""
    return Table(columns={n: table.columns[n] for n in names},
                 nvalid=table.nvalid)


def head(table: Table, n) -> Table:
    return table.with_nvalid(jnp.minimum(table.nvalid, jnp.int32(n)))


def take(table: Table, idx: jax.Array, count) -> Table:
    return table.gather_rows(idx, count)


def concat(a: Table, b: Table) -> Table:
    """Union-all of two same-schema tables (capacity = sum of capacities)."""
    if set(a.names) != set(b.names):
        raise ValueError(f"schema mismatch: {a.names} vs {b.names}")
    cap_a, cap_b = a.capacity, b.capacity
    out_cap = cap_a + cap_b
    i = jnp.arange(out_cap, dtype=jnp.int32)
    from_a = i < a.nvalid
    ia = jnp.clip(i, 0, cap_a - 1)
    ib = jnp.clip(i - a.nvalid, 0, cap_b - 1)
    cols = {}
    for n in a.names:
        ca, cb = a.columns[n], b.columns[n].astype(a.columns[n].dtype)
        cols[n] = jnp.where(from_a, ca[ia], cb[ib])
    return Table(columns=cols, nvalid=a.nvalid + b.nvalid)


def append_rows(acc: Table, t: Table):
    """Append ``t``'s valid rows after ``acc``'s, *keeping acc's static
    capacity* (unlike :func:`concat`, which grows it).

    The fixed-capacity accumulator op behind the morsel-driven chunk
    loops (``core/morsel.py``): under ``jit`` the accumulator's shape
    never changes, so every chunk iteration reuses one compiled program.
    Rows past ``acc.capacity`` are dropped and **counted** — the same
    counted-overflow contract as the shuffle — and the count is returned:
    ``(appended, dropped)``.
    """
    if set(acc.names) != set(t.names):
        raise ValueError(f"schema mismatch: {acc.names} vs {t.names}")
    cap = acc.capacity
    i = jnp.arange(t.capacity, dtype=jnp.int32)
    slot = acc.nvalid + i
    ok = (i < t.nvalid) & (slot < cap)
    flat = jnp.where(ok, slot, cap)
    cols = {}
    for n in acc.names:
        src = t.columns[n].astype(acc.columns[n].dtype)
        buf = jnp.concatenate(
            [acc.columns[n], jnp.zeros((1,), acc.columns[n].dtype)])
        cols[n] = buf.at[flat].set(src)[:cap]
    total = acc.nvalid + t.nvalid
    out = Table(columns=cols, nvalid=jnp.minimum(total, cap))
    return out, jnp.maximum(total - cap, 0)


# --------------------------------------------------------------------------
# OrderBy (sort_values)
# --------------------------------------------------------------------------


def _sort_key(col: jax.Array, ascending: bool) -> jax.Array:
    if ascending:
        return col
    if jnp.issubdtype(col.dtype, jnp.floating):
        return -col
    return ~col  # two's-complement: exact order reversal, no overflow


def sort_values(table: Table, by: Sequence[str],
                ascending: bool | Sequence[bool] = True, *,
                impl: str | None = None,
                kernel_impl: str | None = None) -> Table:
    """Paper's OrderBy: stable multi-key sort; padding rows stay at the end.

    ``impl`` picks the backend (default ``kernel_backend.sort_impl()``):
    ``"xla"`` (one stable ``jax.lax.sort``) or ``"radix"`` (multi-pass LSD
    radix rank on the ``kernels/radix_sort`` engine — no ``sort``
    primitive in the jaxpr).  Both emit *bit-identical* output — same
    rows, same order, same dtypes, including the stable order of equal
    keys and the padding region — so they are drop-in interchangeable
    (conformance: tests/test_sort_backends.py).  ``kernel_impl``
    (ref | pallas | pallas_interpret) selects the radix digit kernel.
    """
    by = list(by)
    if isinstance(ascending, bool):
        ascending = [ascending] * len(by)
    impl = impl or _default_sort_impl()
    keys = [_sort_key(table.columns[k], a) for k, a in zip(by, ascending)]
    if impl == "xla":
        invalid = (~table.valid_mask).astype(jnp.int32)
        iota = jnp.arange(table.capacity, dtype=jnp.int32)
        out = jax.lax.sort((invalid, *keys, iota), num_keys=1 + len(keys),
                           is_stable=True)
        perm = out[-1]
    elif impl == "radix":
        perm = radix_permutation(
            tuple(keys), ~table.valid_mask,
            impl=kernel_impl or _default_kernel_impl())
    else:
        raise ValueError(f"unknown sort impl {impl!r} "
                         "(expected 'xla' or 'radix')")
    return table.gather_rows(perm, table.nvalid)


# --------------------------------------------------------------------------
# Lexicographic vectorized binary search (exact, multi-key, static shape)
# --------------------------------------------------------------------------


def _tuple_less(a: tuple, b: tuple) -> jax.Array:
    """a < b lexicographically (element-wise over vectors)."""
    res = jnp.zeros(a[0].shape, bool)
    eq = jnp.ones(a[0].shape, bool)
    for x, y in zip(a, b):
        res = res | (eq & (x < y))
        eq = eq & (x == y)
    return res


def lex_searchsorted(sorted_keys: tuple, query_keys: tuple,
                     side: str = "left") -> jax.Array:
    """``searchsorted`` over a tuple of parallel sorted key columns.

    ``sorted_keys[i]`` all share shape ``(n,)`` and are lexicographically
    sorted; ``query_keys[i]`` share shape ``(m,)``.  Returns int32 ``(m,)``
    insertion points.  Exact (comparison-based), O(m log n).
    """
    n = sorted_keys[0].shape[0]
    m = query_keys[0].shape[0]
    lo = jnp.zeros((m,), jnp.int32)
    hi = jnp.full((m,), n, jnp.int32)
    iters = max(1, int(n - 1).bit_length() + 1) if n > 0 else 1

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        midc = jnp.clip(mid, 0, n - 1)
        at_mid = tuple(k[midc] for k in sorted_keys)
        if side == "left":
            go_right = _tuple_less(at_mid, query_keys)        # k[mid] < q
        else:
            go_right = ~_tuple_less(query_keys, at_mid)       # k[mid] <= q
        go_right = go_right & (mid < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo


def _sorted_keys_with_sentinel(table: Table, by: Sequence[str]):
    """Sort table by ``by``; overwrite padding keys with +max sentinels so the
    full-capacity key arrays are globally sorted."""
    ts = sort_values(table, by)
    valid = ts.valid_mask
    keys = []
    for k in by:
        col = ts.columns[k]
        keys.append(jnp.where(valid, col, _sentinel_max(col)))
    return ts, tuple(keys)


# --------------------------------------------------------------------------
# Unique / drop_duplicates
# --------------------------------------------------------------------------


def drop_duplicates(table: Table, subset: Sequence[str] | None = None, *,
                    impl: str | None = None, return_overflow: bool = False,
                    num_buckets: int | None = None,
                    bucket_capacity: int | None = None,
                    kernel_impl: str | None = None):
    """Keep the first occurrence of each distinct key (paper: Unique).

    ``impl`` picks the backend (default ``kernel_backend.groupby_impl()``):
    ``"sort"`` (stable sort + boundary compaction) or ``"hash"`` (key-only
    hash groupby on the ``kernels/hash_groupby`` plan — no sort).  Both
    emit the *canonical* table: one row per distinct key, sorted by the
    ``subset`` columns, payload columns taken from the key's first
    occurrence — bit-identical across backends.  The hash backend adds
    static ``num_buckets`` / ``bucket_capacity`` sizing (auto-sized from
    the capacity when omitted); rows overflowing a bucket slab are
    dropped and counted (``return_overflow=True`` returns the count).
    """
    subset = list(subset) if subset is not None else list(table.names)
    impl = impl or _default_groupby_impl()
    if impl == "sort":
        out, over = _sort_drop_duplicates(table, subset), jnp.int32(0)
    elif impl == "hash":
        out, over = _hash_drop_duplicates(table, subset, num_buckets,
                                          bucket_capacity, kernel_impl)
    else:
        raise ValueError(f"unknown groupby impl {impl!r} "
                         "(expected 'sort' or 'hash')")
    if return_overflow:
        return out, over
    return out


def _sort_drop_duplicates(table: Table, subset: list) -> Table:
    ts = sort_values(table, subset)
    valid = ts.valid_mask
    neq_prev = jnp.zeros(ts.capacity, bool)
    for k in subset:
        col = ts.columns[k]
        prev = jnp.roll(col, 1)
        neq_prev = neq_prev | (col != prev)
    first = jnp.arange(ts.capacity) == 0
    boundary = (first | neq_prev) & valid
    return compact(ts, boundary)


def _hash_drop_duplicates(table: Table, subset: list, num_buckets,
                          bucket_capacity, kernel_impl):
    """Key-only hash groupby: the plan's group representatives *are* the
    first occurrences; ranking them by key reproduces the sort backend's
    output exactly — without a sort."""
    plan = _run_hash_groupby_plan(table, subset, (), num_buckets,
                                  bucket_capacity, kernel_impl)
    _, grow, final, ngroups, cap = _canonical_group_layout(
        table, subset, plan, kernel_impl)
    out_cols = {n: _place_groups(table.columns[n][grow], final, cap)
                for n in table.names}
    return Table(columns=out_cols, nvalid=ngroups), plan.dropped


unique = drop_duplicates


# --------------------------------------------------------------------------
# GroupBy + Aggregate
# --------------------------------------------------------------------------

_AGGS = ("sum", "count", "mean", "min", "max")


def groupby_aggregate(table: Table, by: Sequence[str],
                      aggs: Mapping[str, Sequence[str] | str], *,
                      impl: str | None = None,
                      return_overflow: bool = False,
                      num_buckets: int | None = None,
                      bucket_capacity: int | None = None,
                      kernel_impl: str | None = None):
    """Paper's GroupBy followed by Aggregate.

    ``aggs`` maps value-column name -> aggregation(s) in
    {sum,count,mean,min,max}.  Output columns are named ``{col}_{agg}``;
    one row per distinct key, capacity preserved.

    ``impl`` picks the backend (default ``kernel_backend.groupby_impl()``):
    ``"sort"`` (lexicographic sort + segment reductions) or ``"hash"``
    (bucketed hash-accumulate on the ``kernels/hash_groupby`` kernel — no
    sort anywhere on the path).  Both emit the *canonical* table: one row
    per distinct key, sorted by the ``by`` columns, counts int32, value
    aggregates float32 — bit-identical across backends (float sum/mean
    bit-identical whenever addition is exact, to rounding otherwise).
    The hash backend adds static ``num_buckets`` / ``bucket_capacity``
    sizing (auto-sized from the capacity when omitted) and ``kernel_impl``
    (ref | pallas | pallas_interpret); rows overflowing a bucket slab are
    dropped and counted (``return_overflow=True`` returns the count).
    """
    by = list(by)
    aggs = {c: [ops] if isinstance(ops, str) else list(ops)
            for c, ops in aggs.items()}
    for ops in aggs.values():
        for op in ops:
            if op not in _AGGS:
                raise ValueError(f"unknown aggregation {op!r}")
    impl = impl or _default_groupby_impl()
    if impl == "sort":
        out, over = _sort_groupby(table, by, aggs), jnp.int32(0)
    elif impl == "hash":
        out, over = _hash_groupby(table, by, aggs, num_buckets,
                                  bucket_capacity, kernel_impl)
    else:
        raise ValueError(f"unknown groupby impl {impl!r} "
                         "(expected 'sort' or 'hash')")
    if return_overflow:
        return out, over
    return out


def _sort_groupby(table: Table, by: list,
                  aggs: Mapping[str, list]) -> Table:
    """Sort backend: lexicographic sort, group-boundary detection, segment
    reductions indexed by group id."""
    ts = sort_values(table, by)
    valid = ts.valid_mask
    cap = ts.capacity
    neq_prev = jnp.zeros(cap, bool)
    for k in by:
        col = ts.columns[k]
        neq_prev = neq_prev | (col != jnp.roll(col, 1))
    boundary = ((jnp.arange(cap) == 0) | neq_prev) & valid
    ngroups = jnp.sum(boundary, dtype=jnp.int32)
    seg = jnp.cumsum(boundary.astype(jnp.int32)) - 1          # 0-based
    # padding rows -> trash segment (cap-1 is free whenever padding exists)
    seg = jnp.where(valid, seg, cap - 1)

    out_cols: dict[str, jax.Array] = {}
    for k in by:
        out_cols[k] = ts.columns[k]
    counts = jax.ops.segment_sum(valid.astype(jnp.int32), seg,
                                 num_segments=cap)
    countf = jnp.maximum(counts, 1).astype(jnp.float32)
    for col_name, ops in aggs.items():
        fcol = ts.columns[col_name].astype(jnp.float32)
        for op in ops:
            if op == "sum":
                v = jax.ops.segment_sum(jnp.where(valid, fcol, 0.0), seg, cap)
            elif op == "count":
                v = counts
            elif op == "mean":
                s = jax.ops.segment_sum(jnp.where(valid, fcol, 0.0), seg, cap)
                v = s / countf
            elif op == "min":
                v = jax.ops.segment_min(
                    jnp.where(valid, fcol, jnp.inf), seg, cap)
            else:  # max
                v = jax.ops.segment_max(
                    jnp.where(valid, fcol, -jnp.inf), seg, cap)
            out_cols[f"{col_name}_{op}"] = v

    # segment g's result sits at index g; boundary row g sits at the g-th
    # boundary position — compacting boundary rows aligns keys with index g.
    key_tbl = compact(Table(columns={k: out_cols[k] for k in by},
                            nvalid=ts.nvalid), boundary)
    cols = dict(key_tbl.columns)
    for name, v in out_cols.items():
        if name not in by:
            cols[name] = v  # already indexed by group id
    return Table(columns=cols, nvalid=ngroups)


def _planned_sizes(bplan: bucketing.BucketPlan, nvalid, capacity: int,
                   num_buckets, explicit_capacity):
    """Distribution-proof static sizing via the two-pass bucket planner.

    Above ``bucketing.EXACT_SLAB_CAP`` the uniform auto-sizing heuristic
    can overflow on skewed keys; when the key bit-planes are *concrete*
    (an eager call — not traced under jit/shard_map) the planner
    histograms the actual bucket loads host-side and sizes the slab to
    cover the real maximum.  The hash it runs is memoized on the
    :class:`~..kernels.bucketing.BucketPlan`, so the kernel plan reuses
    the same bucket ids instead of re-hashing.  Returns ``(num_buckets,
    bucket_capacity)`` or ``None`` when planning is not applicable
    (explicit capacity, exact-slab range, or traced inputs — the
    heuristic applies there).
    """
    if explicit_capacity is not None or capacity <= bucketing.EXACT_SLAB_CAP:
        return None
    if isinstance(nvalid, jax.core.Tracer) or not bplan.concrete:
        return None
    n = int(nvalid)
    B, C = bucketing.plan_bucket_sizes(num_buckets=num_buckets,
                                       plan=bplan, nvalid=n)
    # slab sizes are static args of the jitted plans: quantize the planned
    # capacity to the next power of two so shifting key distributions
    # retrace at most log2(capacity) times, not once per observed load
    return B, 1 << max(3, (C - 1).bit_length())


def _run_hash_groupby_plan(table: Table, by: list, value_cols: tuple,
                           num_buckets, bucket_capacity, kernel_impl):
    keys = tuple(table.columns[k] for k in by)
    bp = bucketing.BucketPlan(keys, table.valid_mask)
    planned = _planned_sizes(bp, table.nvalid, table.capacity,
                             num_buckets, bucket_capacity)
    if planned is not None:
        B, C = planned
        bid = bp.bucket_ids_for(B)   # the sizing pass's hash, reused
    else:
        B, C = default_hash_groupby_sizes(table.capacity, num_buckets)
        C = bucket_capacity or C
        bid = None
    return hash_groupby_plan(
        bp.bits, table.valid_mask,
        tuple(table.columns[c] for c in value_cols),
        num_buckets=B, bucket_capacity=C,
        impl=kernel_impl or _default_kernel_impl(), bid=bid)


def _canonical_group_layout(table: Table, by: list, plan,
                            kernel_impl: str | None = None):
    """Map the plan's group representatives to canonical (key-sorted)
    output rows without a sort.

    Representatives are first compacted bucket-major (scatter by running
    count), then each group's key — gathered from its first-occurrence
    row — is ranked by the ``kernels/radix_sort`` multi-pass radix rank:
    group keys are globally distinct (equal keys share a bucket), so each
    valid group's stable rank is a bijection onto ``[0, ngroups)``.
    O(passes * capacity * 2^radix_bits) counting work — linear in the
    capacity, replacing the earlier O(capacity^2) pairwise count-smaller
    — and still no ``sort`` primitive.

    Returns (scat, grow, final, ngroups, cap): the slab->compact scatter
    function (for the plan's per-slot aggregates), per compacted group
    its representative row, its canonical output slot (``cap`` = trash),
    the group count, and the output capacity.
    """
    cap = table.capacity
    rep = plan.rep.reshape(-1) > 0
    ridx = jnp.cumsum(rep.astype(jnp.int32)) - 1   # ridx < cap: one rep/row
    ngroups = jnp.sum(rep, dtype=jnp.int32)
    slot = jnp.where(rep, ridx, cap)

    def scat(x):
        return jnp.zeros((cap + 1,), x.dtype).at[slot].set(x)[:cap]

    grow = scat(plan.row.reshape(-1))
    gvalid = jnp.zeros((cap + 1,), bool).at[slot].set(rep)[:cap]
    gkeys = tuple(table.columns[k][grow] for k in by)
    rank = radix_rank(gkeys, ~gvalid,
                      impl=kernel_impl or _default_kernel_impl())
    final = jnp.where(gvalid, rank, cap)
    return scat, grow, final, ngroups, cap


def _place_groups(x: jax.Array, final: jax.Array, cap: int) -> jax.Array:
    """Scatter compacted group entries into their canonical slots."""
    return jnp.zeros((cap + 1,), x.dtype).at[final].set(x)[:cap]


def _hash_groupby(table: Table, by: list, aggs: Mapping[str, list],
                  num_buckets, bucket_capacity, kernel_impl):
    """Hash backend: bucketed hash-accumulate (kernels/hash_groupby)
    instead of a sort.  The plan aggregates every distinct key inside its
    hash bucket in one dense pass; canonical key order is recovered with
    the multi-pass radix rank (no sort primitive on this path)."""
    value_cols = tuple(aggs)
    plan = _run_hash_groupby_plan(table, by, value_cols, num_buckets,
                                  bucket_capacity, kernel_impl)
    scat, grow, final, ngroups, cap = _canonical_group_layout(
        table, by, plan, kernel_impl)
    out_cols: dict[str, jax.Array] = {
        k: _place_groups(table.columns[k][grow], final, cap) for k in by}
    counts = _place_groups(scat(plan.counts.reshape(-1)), final, cap)
    countf = jnp.maximum(counts, 1).astype(jnp.float32)
    for i, (col_name, ops) in enumerate(aggs.items()):
        s = _place_groups(scat(plan.sums[:, i, :].reshape(-1)), final, cap)
        for op in ops:
            if op == "sum":
                v = s
            elif op == "count":
                v = counts
            elif op == "mean":
                v = s / countf
            elif op == "min":
                v = _place_groups(scat(plan.mins[:, i, :].reshape(-1)),
                                  final, cap)
            else:  # max
                v = _place_groups(scat(plan.maxs[:, i, :].reshape(-1)),
                                  final, cap)
            out_cols[f"{col_name}_{op}"] = v
    return Table(columns=out_cols, nvalid=ngroups), plan.dropped


# merge rule per partial-aggregate column suffix: how two partials of the
# same group combine into the partial of their union
_PARTIAL_MERGE = {"sum": "sum", "count": "sum", "min": "min", "max": "max"}


def partial_agg_columns(aggs: Mapping[str, Sequence[str] | str]):
    """Expand requested aggregations to the *partial* set that chunked
    (morsel) execution accumulates: ``mean`` needs ``sum`` + ``count``,
    everything else is its own partial.  Returns ``{col: [partial ops]}``
    in canonical (sum, count, min, max) order."""
    out: dict[str, list] = {}
    for col, ops in aggs.items():
        ops = [ops] if isinstance(ops, str) else list(ops)
        need = set()
        for op in ops:
            if op not in _AGGS:
                raise ValueError(f"unknown aggregation {op!r}")
            need.update(("sum", "count") if op == "mean" else (op,))
        out[col] = [op for op in ("sum", "count", "min", "max")
                    if op in need]
    return out


def merge_partial_aggregates(acc: Table, part: Table, by: Sequence[str], *,
                             impl: str | None = None,
                             return_overflow: bool = False,
                             num_buckets: int | None = None,
                             bucket_capacity: int | None = None,
                             kernel_impl: str | None = None):
    """Merge two canonical partial-aggregate tables into one with
    ``acc``'s capacity — the associative combine step of morsel-driven
    groupby (``core/morsel.py``).

    Both inputs carry the ``by`` key columns plus partial columns named
    ``{col}_{op}`` with ``op`` in sum/count/min/max (the shape
    :func:`groupby_aggregate` emits, see :func:`partial_agg_columns`).
    Equal keys combine through the matching merge reduction — sum of
    sums, sum of counts, min of mins, max of maxs — by re-running the
    pluggable aggregation backend (``impl`` = 'sort' | 'hash': the merge
    reuses the existing hash-groupby slabs, no new kernel) over the
    concatenation, so the output is again canonical (one row per key,
    key-sorted) and the merge is associative: any chunking of the input
    rows folds to the same table.

    Counts stay exact int32 (the float32 re-sum is exact below 2^24 rows
    per group — the engine's whole-table capacity bound is int32, and
    per-chunk partial counts are bounded by chunk capacity).  Groups past
    ``acc.capacity`` (and hash-slab overflow under ``impl='hash'``) are
    dropped and **counted**: ``return_overflow=True`` returns
    ``(merged, dropped)``.
    """
    by = list(by)
    t = concat(acc, part)
    merge_op: dict[str, str] = {}
    for name in acc.names:
        if name in by:
            continue
        _, _, suffix = name.rpartition("_")
        if suffix not in _PARTIAL_MERGE:
            raise ValueError(
                f"column {name!r} is not a partial-aggregate column "
                "(expected a _sum/_count/_min/_max suffix)")
        merge_op[name] = _PARTIAL_MERGE[suffix]
    g, over = groupby_aggregate(t, by, {n: [op] for n, op in
                                        merge_op.items()},
                                impl=impl, return_overflow=True,
                                num_buckets=num_buckets,
                                bucket_capacity=bucket_capacity,
                                kernel_impl=kernel_impl)
    cap = acc.capacity
    cols = {k: g.columns[k][:cap] for k in by}
    for name, op in merge_op.items():
        v = g.columns[f"{name}_{op}"][:cap]
        if name.endswith("_count"):
            v = v.astype(jnp.int32)
        cols[name] = v
    out = Table(columns=cols, nvalid=jnp.minimum(g.nvalid, cap))
    dropped = over + jnp.maximum(g.nvalid - cap, 0)
    if return_overflow:
        return out, dropped
    return out


def aggregate(table: Table, col: str, op: str) -> jax.Array:
    """Whole-column masked reduction -> scalar (paper's Aggregate).

    ``count`` returns int32 (matching the groupby backends' count
    columns); every other aggregation returns float32."""
    valid = table.valid_mask
    x = table.columns[col].astype(jnp.float32)
    n = jnp.maximum(table.nvalid.astype(jnp.float32), 1.0)
    if op == "sum":
        return jnp.sum(jnp.where(valid, x, 0.0))
    if op == "count":
        return table.nvalid.astype(jnp.int32)
    if op == "mean":
        return jnp.sum(jnp.where(valid, x, 0.0)) / n
    if op == "min":
        return jnp.min(jnp.where(valid, x, jnp.inf))
    if op == "max":
        return jnp.max(jnp.where(valid, x, -jnp.inf))
    if op == "std":
        m = jnp.sum(jnp.where(valid, x, 0.0)) / n
        v = jnp.sum(jnp.where(valid, (x - m) ** 2, 0.0)) / n
        return jnp.sqrt(v)
    raise ValueError(f"unknown aggregation {op!r}")


# --------------------------------------------------------------------------
# Join (pluggable backend: sort-merge / bucketed hash; static output
# capacity either way)
# --------------------------------------------------------------------------


def join(left: Table, right: Table, *,
         left_on: Sequence[str], right_on: Sequence[str] | None = None,
         how: str = "inner", out_capacity: int | None = None,
         suffix: str = "_r", return_overflow: bool = False,
         impl: str | None = None, num_buckets: int | None = None,
         bucket_capacity: int | None = None,
         probe_capacity: int | None = None,
         kernel_impl: str | None = None):
    """Paper's Join: inner/left join with static output capacity.

    ``impl`` picks the backend (default ``kernel_backend.join_impl()``):
    ``"sortmerge"`` or ``"hash"``.  Both emit *identical* output — same
    rows, same order: left-row-major, and within a left row its matches in
    the right table's original row order — so they are drop-in
    interchangeable (conformance: tests/test_join_backends.py).

    ``out_capacity`` defaults to ``left.capacity``; overflowing output
    rows are dropped and counted (``return_overflow=True`` returns the
    count).  The hash backend adds ``num_buckets`` / ``bucket_capacity`` /
    ``probe_capacity`` static sizing (auto-sized from the table capacities
    when omitted; rows overflowing a bucket slab are dropped and counted
    into the same overflow metric) and ``kernel_impl``
    (ref | pallas | pallas_interpret) for the probe kernel.
    """
    if how not in ("inner", "left"):
        raise ValueError("how must be 'inner' or 'left'")
    impl = impl or _default_join_impl()
    left_on = list(left_on)
    right_on = list(right_on) if right_on is not None else left_on
    out_cap = out_capacity or left.capacity
    if impl == "sortmerge":
        return _sortmerge_join(left, right, left_on, right_on, how, out_cap,
                               suffix, return_overflow)
    if impl == "hash":
        return _hash_join(left, right, left_on, right_on, how, out_cap,
                          suffix, return_overflow, num_buckets,
                          bucket_capacity, probe_capacity, kernel_impl)
    raise ValueError(f"unknown join impl {impl!r} "
                     "(expected 'sortmerge' or 'hash')")


def _emit_layout(match_counts: jax.Array, lvalid: jax.Array, how: str):
    """(inclusive cumsum, exclusive offsets, total) of per-left-row emit
    counts — the left-row-major layout shared by both join backends (left
    join emits 1 slot for each ``lvalid`` row with no matches)."""
    if how == "left":
        emit = jnp.where(lvalid & (match_counts == 0), 1, match_counts)
    else:
        emit = match_counts
    cum = jnp.cumsum(emit)
    offs = cum - emit
    total = cum[-1] if emit.shape[0] > 0 else jnp.int32(0)
    return cum, offs, total


def _sortmerge_join(left: Table, right: Table, left_on, right_on, how,
                    out_cap, suffix, return_overflow):
    """Sort-merge backend: the right table is sorted by its keys; each left
    row binary-searches its match range ``[lo, hi)``; output slot ``j`` is
    mapped back to its (left row, match offset) pair with a second
    searchsorted — fully vectorized, no dynamic shapes."""
    rs, rkeys = _sorted_keys_with_sentinel(right, right_on)
    # compare every key pair in the *promoted* common dtype (casting the
    # sorted keys is order-preserving: int32 -> float32 is monotonic), so
    # a mixed-dtype probe cannot collide distinct keys
    dts = tuple(jnp.promote_types(left.columns[k].dtype,
                                  rs.columns[rk].dtype)
                for k, rk in zip(left_on, right_on))
    qkeys = tuple(left.columns[k].astype(dt)
                  for k, dt in zip(left_on, dts))
    rkeys = tuple(rk.astype(dt) for rk, dt in zip(rkeys, dts))
    lo = lex_searchsorted(rkeys, qkeys, side="left")
    hi = lex_searchsorted(rkeys, qkeys, side="right")
    lo = jnp.minimum(lo, right.nvalid)
    hi = jnp.minimum(hi, right.nvalid)
    lvalid = left.valid_mask
    match_counts = jnp.where(lvalid, hi - lo, 0)
    cum, offs, total = _emit_layout(match_counts, lvalid, how)

    j = jnp.arange(out_cap, dtype=jnp.int32)
    lrow = jnp.searchsorted(cum, j, side="right").astype(jnp.int32)
    lrow = jnp.clip(lrow, 0, left.capacity - 1)
    within = j - offs[lrow]
    matched = within < match_counts[lrow]
    rrow = jnp.clip(lo[lrow] + within, 0, max(right.capacity - 1, 0))

    cols: dict[str, jax.Array] = {}
    for n in left.names:
        cols[n] = left.columns[n][lrow]
    drop_keys = set(right_on) if left_on == right_on else set()
    for n in rs.names:
        if n in drop_keys:
            continue
        name = n + suffix if n in cols else n
        v = rs.columns[n][rrow]
        if how == "left":
            v = jnp.where(matched, v, null_like(v))
        cols[name] = v
    out = Table(columns=cols, nvalid=jnp.minimum(total, out_cap))
    if return_overflow:
        return out, jnp.maximum(total - out_cap, 0)
    return out


def _hash_join(left: Table, right: Table, left_on, right_on, how,
               out_cap, suffix, return_overflow, num_buckets,
               bucket_capacity, probe_capacity, kernel_impl):
    """Hash backend: bucketed build+probe (kernels/hash_join) instead of
    two sorts.  The plan yields per-left-row match counts plus per
    (probe slot, chain slot) match ranks; matched pairs are scattered into
    their output slots (offset of the left row + rank of the match), which
    reproduces the sort-merge output ordering exactly because chain order
    is original-right-row order."""
    B, C, Lc = default_hash_join_sizes(left.capacity, right.capacity,
                                       num_buckets)
    # compare in the promoted common dtype (same rule as the sort-merge
    # backend): the hash only picks the bucket, equality is on the
    # promoted key bits.  Bit-planes are extracted ONCE per side here and
    # shared by the sizing pass and the kernel plan (BucketPlan).
    qkeys, rkeys = _promoted_semi_keys(left, right, list(left_on),
                                       list(right_on))
    lbp = bucketing.BucketPlan(qkeys, left.valid_mask)
    rbp = bucketing.BucketPlan(rkeys, right.valid_mask)
    # two-pass planner (concrete keys, above the exact-slab range): size
    # the build chains / probe slabs to the real per-bucket maxima
    big = max(left.capacity, right.capacity)
    built = _planned_sizes(rbp, right.nvalid, big, B, bucket_capacity)
    if built is not None:
        C = built[1]
    probed = _planned_sizes(lbp, left.nvalid, big, B, probe_capacity)
    if probed is not None:
        Lc = probed[1]
    C = bucket_capacity or C
    Lc = probe_capacity or Lc
    plan = hash_join_plan(lbp.bits, left.valid_mask, rbp.bits,
                          right.valid_mask,
                          num_buckets=B, bucket_capacity=C,
                          probe_capacity=Lc,
                          impl=kernel_impl or _default_kernel_impl(),
                          left_bid=(lbp.bucket_ids_for(B)
                                    if probed is not None else None),
                          right_bid=(rbp.bucket_ids_for(B)
                                     if built is not None else None))

    # a probe-dropped left row's match status is unknown: it is excluded
    # from emission entirely (counted in probe_dropped), never emitted as
    # a fake unmatched row — "overflow rows are dropped and counted"
    lvalid = left.valid_mask & plan.probed
    mc = plan.match_counts
    cum, offs, total = _emit_layout(mc, lvalid, how)

    # ONE scatter over the pair space: each matched (bucket, probe slot,
    # chain slot) pair writes its own flat pair index to output slot
    # offs[left row] + within-row match rank; the row ids are then
    # *decoded* from the pair index with out_cap-sized gathers (pair //
    # C walks the probe slots, so probe_row/build_row recover the
    # original rows) instead of scattering three pair-space planes.
    slot = offs[plan.probe_row][:, :, None] + plan.rank      # (B, Lc, C)
    keep = (plan.rank >= 0) & (slot < out_cap)
    flat = jnp.where(keep, slot, out_cap).reshape(-1)
    npairs = B * Lc * C
    pair_ids = jnp.arange(npairs, dtype=jnp.int32)
    buf = (jnp.full((out_cap + 1,), -1, jnp.int32)
           .at[flat].set(pair_ids)[:out_cap])
    matched = buf >= 0
    pp = jnp.maximum(buf, 0)
    # pair = (b*Lc + l)*C + c  ->  probe slot index b*Lc+l = pair // C,
    # build slot index b*C + c = (pair // (Lc*C))*C + pair % C
    out_lrow = jnp.where(matched,
                         plan.probe_row.reshape(-1)[pp // C], 0)
    out_rrow = jnp.where(
        matched,
        plan.build_row.reshape(-1)[(pp // (Lc * C)) * C + pp % C], 0)
    if how == "left":
        un = lvalid & (mc == 0)
        flat_u = jnp.where(un & (offs < out_cap), offs, out_cap)
        ubuf = (jnp.zeros((out_cap + 1,), jnp.int32)
                .at[flat_u].set(jnp.arange(left.capacity, dtype=jnp.int32))
                [:out_cap])
        out_lrow = jnp.where(matched, out_lrow, ubuf)

    cols: dict[str, jax.Array] = {}
    for n in left.names:
        cols[n] = left.columns[n][out_lrow]
    drop_keys = set(right_on) if left_on == right_on else set()
    for n in right.names:
        if n in drop_keys:
            continue
        name = n + suffix if n in cols else n
        v = right.columns[n][out_rrow]
        if how == "left":
            v = jnp.where(matched, v, null_like(v))
        cols[name] = v
    out = Table(columns=cols, nvalid=jnp.minimum(total, out_cap))
    if return_overflow:
        overflow = (jnp.maximum(total - out_cap, 0)
                    + plan.build_dropped + plan.probe_dropped)
        return out, overflow
    return out


def cartesian_product(left: Table, right: Table, out_capacity: int,
                      suffix: str = "_r", return_overflow: bool = False):
    """Paper's Cartesian Product (static output capacity).

    Output rows beyond ``out_capacity`` are dropped and *counted* — the
    same "dropped and counted" contract as join/groupby overflow
    (``return_overflow=True`` returns the count; callers size the
    capacity so it stays zero)."""
    n2 = jnp.maximum(right.nvalid, 1)
    j = jnp.arange(out_capacity, dtype=jnp.int32)
    lrow = jnp.clip(j // n2, 0, max(left.capacity - 1, 0))
    rrow = jnp.clip(j % n2, 0, max(right.capacity - 1, 0))
    total = left.nvalid * right.nvalid
    cols = {n: left.columns[n][lrow] for n in left.names}
    for n in right.names:
        name = n + suffix if n in cols else n
        cols[name] = right.columns[n][rrow]
    out = Table(columns=cols, nvalid=jnp.minimum(total, out_capacity))
    if return_overflow:
        return out, jnp.maximum(total - out_capacity, 0)
    return out


# --------------------------------------------------------------------------
# Membership + set operators (pluggable semi-join backend: sort-merge /
# bucketed hash membership probe — no join materialization either way)
# --------------------------------------------------------------------------


def _promoted_semi_keys(left: Table, right: Table, left_on: list,
                        right_on: list):
    """Both sides' key columns cast to their *promoted* common dtype.

    Comparing in either side's dtype can collide distinct keys (e.g. a
    float32 3.7 probe truncated to int32 3), so membership — like the
    join backends — compares every key pair in ``jnp.promote_types`` of
    the two column dtypes (int32 x float32 -> float32)."""
    q, v = [], []
    for lk, rk in zip(left_on, right_on):
        lc, rc = left.columns[lk], right.columns[rk]
        dt = jnp.promote_types(lc.dtype, rc.dtype)
        q.append(lc.astype(dt))
        v.append(rc.astype(dt))
    return tuple(q), tuple(v)


def _sortmerge_semi(qkeys: tuple, lvalid: jax.Array, vkeys: tuple,
                    rnvalid) -> jax.Array:
    """Sort-merge membership: sort the right key set, binary-search each
    left key's match range — member iff the range is non-empty."""
    vt = Table(columns={f"k{i}": c for i, c in enumerate(vkeys)},
               nvalid=rnvalid)
    _, skeys = _sorted_keys_with_sentinel(vt, list(vt.names))
    lo = lex_searchsorted(skeys, qkeys, side="left")
    hi = lex_searchsorted(skeys, qkeys, side="right")
    lo = jnp.minimum(lo, rnvalid)
    hi = jnp.minimum(hi, rnvalid)
    return (hi > lo) & lvalid


def _hash_semi(qkeys: tuple, left: Table, vkeys: tuple, right: Table,
               num_buckets, bucket_capacity, probe_capacity, kernel_impl):
    """Hash membership: build the right side's key set into bucket slabs
    (kernels/hash_semi, the hash_groupby/bucketing slab plan) and probe
    each left key — one boolean per row, no join materialization, no
    sort primitive.  Probe-dropped rows report False and are counted."""
    B, C, Lc = default_hash_semi_sizes(left.capacity, right.capacity,
                                       num_buckets)
    # bit-planes extracted ONCE per side, shared by the sizing pass and
    # the kernel plan (BucketPlan caches the hash between them)
    lbp = bucketing.BucketPlan(qkeys, left.valid_mask)
    rbp = bucketing.BucketPlan(vkeys, right.valid_mask)
    # two-pass planner (concrete keys, above the exact-slab range): size
    # the build/probe slabs to the real per-bucket maxima
    big = max(left.capacity, right.capacity)
    built = _planned_sizes(rbp, right.nvalid, big, B, bucket_capacity)
    if built is not None:
        C = built[1]
    probed = _planned_sizes(lbp, left.nvalid, big, B, probe_capacity)
    if probed is not None:
        Lc = probed[1]
    C = bucket_capacity or C
    Lc = probe_capacity or Lc
    plan = hash_semi_plan(lbp.bits, left.valid_mask, rbp.bits,
                          right.valid_mask,
                          num_buckets=B, bucket_capacity=C,
                          probe_capacity=Lc,
                          impl=kernel_impl or _default_kernel_impl(),
                          left_bid=(lbp.bucket_ids_for(B)
                                    if probed is not None else None),
                          right_bid=(rbp.bucket_ids_for(B)
                                     if built is not None else None))
    mask = plan.member & left.valid_mask
    return mask, plan.build_dropped + plan.probe_dropped


def semi_mask(left: Table, right: Table, left_on: Sequence[str],
              right_on: Sequence[str] | None = None, *,
              impl: str | None = None, return_overflow: bool = False,
              num_buckets: int | None = None,
              bucket_capacity: int | None = None,
              probe_capacity: int | None = None,
              kernel_impl: str | None = None):
    """Semi-join membership mask: per left row, does its key appear among
    the right table's valid keys?

    ``impl`` picks the backend (default ``kernel_backend.semi_impl()`` /
    ``REPRO_SEMI_IMPL``): ``"sortmerge"`` (binary search over the sorted
    right key set) or ``"hash"`` (bucketed build+probe membership on the
    ``kernels/hash_semi`` plan — no join materialization, no ``sort``
    primitive anywhere on the path).  Both emit the *bit-identical* mask
    — key pairs are compared in their promoted common dtype either way —
    so they are drop-in interchangeable (conformance:
    tests/test_setop_backends.py).

    The hash backend adds static ``num_buckets`` / ``bucket_capacity`` /
    ``probe_capacity`` sizing (auto-sized from the table capacities when
    omitted) and ``kernel_impl`` (ref | pallas | pallas_interpret); rows
    overflowing a slab are dropped — reported non-member — and counted
    (``return_overflow=True`` returns the count)."""
    left_on = list(left_on)
    right_on = list(right_on) if right_on is not None else left_on
    impl = impl or _default_semi_impl()
    qkeys, vkeys = _promoted_semi_keys(left, right, left_on, right_on)
    if impl == "sortmerge":
        mask, over = _sortmerge_semi(qkeys, left.valid_mask, vkeys,
                                     right.nvalid), jnp.int32(0)
    elif impl == "hash":
        mask, over = _hash_semi(qkeys, left, vkeys, right, num_buckets,
                                bucket_capacity, probe_capacity,
                                kernel_impl)
    else:
        raise ValueError(f"unknown semi impl {impl!r} "
                         "(expected 'sortmerge' or 'hash')")
    if return_overflow:
        return mask, over
    return mask


def _semi_mask(left: Table, right: Table, on: Sequence[str],
               **kwargs):
    """Same-named-columns :func:`semi_mask` (the set operators' shape)."""
    return semi_mask(left, right, on, on, **kwargs)


def isin(table: Table, col: str, values: Table, values_col: str, *,
         impl: str | None = None, return_overflow: bool = False,
         num_buckets: int | None = None, bucket_capacity: int | None = None,
         probe_capacity: int | None = None, kernel_impl: str | None = None):
    """Bool mask: table[col] present among valid values[values_col].

    A single-key :func:`semi_mask` — the paper's membership filter
    (UNOMT Fig. 11).  Keys are compared in the promoted common dtype of
    the two columns, so e.g. a float32 probe against an int32 values
    table cannot collide distinct keys.  See :func:`semi_mask` for the
    backend (``impl`` / ``REPRO_SEMI_IMPL``) and overflow contracts."""
    return semi_mask(table, values, [col], [values_col], impl=impl,
                     return_overflow=return_overflow,
                     num_buckets=num_buckets,
                     bucket_capacity=bucket_capacity,
                     probe_capacity=probe_capacity, kernel_impl=kernel_impl)


def intersect(a: Table, b: Table, on: Sequence[str] | None = None, *,
              impl: str | None = None, dedup_impl: str | None = None,
              return_overflow: bool = False,
              num_buckets: int | None = None,
              bucket_capacity: int | None = None,
              probe_capacity: int | None = None,
              kernel_impl: str | None = None):
    """Paper's Intersect: distinct rows of ``a`` present in ``b``.

    ``impl`` selects the semi-join backend (see :func:`semi_mask`);
    ``dedup_impl`` the dedup backend (see :func:`drop_duplicates`,
    default ``kernel_backend.groupby_impl()``).  Output is the canonical
    table (one row per distinct key, sorted by the ``on`` columns) —
    bit-identical across all backend combinations.
    ``return_overflow=True`` returns the summed semi + dedup overflow."""
    on = list(on) if on is not None else list(a.names)
    mask, s_over = _semi_mask(a, b, on, impl=impl, return_overflow=True,
                              num_buckets=num_buckets,
                              bucket_capacity=bucket_capacity,
                              probe_capacity=probe_capacity,
                              kernel_impl=kernel_impl)
    out, d_over = drop_duplicates(compact(a, mask), on, impl=dedup_impl,
                                  return_overflow=True,
                                  kernel_impl=kernel_impl)
    if return_overflow:
        return out, s_over + d_over
    return out


def difference(a: Table, b: Table, on: Sequence[str] | None = None, *,
               impl: str | None = None, return_overflow: bool = False,
               num_buckets: int | None = None,
               bucket_capacity: int | None = None,
               probe_capacity: int | None = None,
               kernel_impl: str | None = None):
    """Paper's Difference: rows of ``a`` with no match in ``b`` (all
    occurrences, original row order).

    ``impl`` selects the semi-join backend (see :func:`semi_mask`); both
    backends emit bit-identical output.  Under the hash backend a
    probe-dropped row's membership is unknown, so it is excluded and
    counted (``return_overflow=True``), never guessed into the output."""
    on = list(on) if on is not None else list(a.names)
    mask, over = _semi_mask(a, b, on, impl=impl, return_overflow=True,
                            num_buckets=num_buckets,
                            bucket_capacity=bucket_capacity,
                            probe_capacity=probe_capacity,
                            kernel_impl=kernel_impl)
    out = compact(a, a.valid_mask & ~mask)
    if return_overflow:
        return out, over
    return out


def union(a: Table, b: Table, on: Sequence[str] | None = None, *,
          impl: str | None = None, return_overflow: bool = False,
          num_buckets: int | None = None,
          bucket_capacity: int | None = None,
          kernel_impl: str | None = None):
    """Paper's Union: concat + dedup on the ``on`` key columns (all
    columns when omitted), keeping each key's first occurrence — ``a``'s
    rows win ties against ``b``'s.

    ``impl`` selects the dedup backend ('sort' | 'hash', see
    :func:`drop_duplicates` / ``REPRO_GROUPBY_IMPL``) with its static
    sizing; rows overflowing a hash bucket slab are dropped and counted
    (``return_overflow=True`` returns the count) — never silently lost."""
    on = list(on) if on is not None else list(a.names)
    return drop_duplicates(concat(a, b), on, impl=impl,
                           return_overflow=return_overflow,
                           num_buckets=num_buckets,
                           bucket_capacity=bucket_capacity,
                           kernel_impl=kernel_impl)


# --------------------------------------------------------------------------
# Null handling (UNOMT ops: isnull / notnull / dropna / fillna)
# --------------------------------------------------------------------------


def isnull(table: Table, col: str) -> jax.Array:
    return isnull_values(table.columns[col]) & table.valid_mask


def dropna(table: Table, subset: Sequence[str] | None = None) -> Table:
    subset = list(subset) if subset is not None else list(table.names)
    bad = jnp.zeros(table.capacity, bool)
    for k in subset:
        bad = bad | isnull_values(table.columns[k])
    return compact(table, ~bad)


def fillna(table: Table, values: Mapping[str, float]) -> Table:
    cols = dict(table.columns)
    for k, v in values.items():
        col = cols[k]
        cols[k] = jnp.where(isnull_values(col),
                            jnp.asarray(v, col.dtype), col)
    return Table(columns=cols, nvalid=table.nvalid)


# --------------------------------------------------------------------------
# Column-wise math used by the UNOMT pipeline (scikit-learn-style scaling)
# --------------------------------------------------------------------------


def column_moments(table: Table, cols: Sequence[str],
                   impl: str | None = None,
                   center: Mapping[str, jax.Array] | None = None):
    """Per-column moments over valid rows: ``({col: sum(x)},
    {col: sum((x - center)**2)}, count)`` float32 scalars.

    ``center`` maps column -> scalar (0.0 when omitted: the raw second
    moment).  Calling twice — first for sums, then centered on the means
    — gives the numerically stable two-pass variance (see
    :func:`standard_scale`); the one-pass ``E[x^2] - m^2`` form
    catastrophically cancels in float32 when ``|mean| >> std``.

    ``impl=None`` uses inline masked reductions (the fast path);
    ``"sort"`` / ``"hash"`` route the same moments through the pluggable
    aggregation backend as a constant-key :func:`groupby_aggregate` — so
    a preprocessing pipeline can exercise one aggregation backend end to
    end (conformance: tests/test_groupby_backends.py).
    """
    center = dict(center) if center is not None else {}
    zero = jnp.float32(0.0)
    if impl is None:
        valid = table.valid_mask
        s1, sd2 = {}, {}
        for k in cols:
            x = table.columns[k].astype(jnp.float32)
            d = x - center.get(k, zero)
            s1[k] = jnp.sum(jnp.where(valid, x, 0.0))
            sd2[k] = jnp.sum(jnp.where(valid, d * d, 0.0))
        return s1, sd2, table.nvalid.astype(jnp.float32)
    cap = table.capacity
    aug = {"__k": jnp.zeros((cap,), jnp.int32)}
    aggs: dict[str, list] = {}
    for k in cols:
        x = table.columns[k].astype(jnp.float32)
        d = x - center.get(k, zero)
        aug[k] = x
        aug[f"__sq_{k}"] = d * d
        aggs[k] = ["sum"]
        aggs[f"__sq_{k}"] = ["sum"]
    # constant key -> a single group in one bucket: the bucket slab must
    # hold every row, so size it to the full capacity explicitly
    g = groupby_aggregate(Table(columns=aug, nvalid=table.nvalid),
                          ["__k"], aggs, impl=impl, num_buckets=8,
                          bucket_capacity=cap)
    nz = table.nvalid > 0
    s1 = {k: jnp.where(nz, g.columns[f"{k}_sum"][0], 0.0) for k in cols}
    sd2 = {k: jnp.where(nz, g.columns[f"__sq_{k}_sum"][0], 0.0)
           for k in cols}
    return s1, sd2, table.nvalid.astype(jnp.float32)


def standard_scale(table: Table, cols: Sequence[str],
                   impl: str | None = None) -> Table:
    """(x - mean) / std per column over valid rows (sklearn StandardScaler).

    Two-pass: mean first, then the variance of deviations about it —
    exact even when ``|mean| >> std``.  ``impl`` selects the moment
    computation (see :func:`column_moments`); the default inline path
    and both aggregation backends agree to float addition-order
    rounding."""
    out = dict(table.columns)
    s1, _, n = column_moments(table, cols, impl=impl)
    n = jnp.maximum(n, 1.0)
    means = {k: s1[k] / n for k in cols}
    _, sd2, _ = column_moments(table, cols, impl=impl, center=means)
    for k in cols:
        x = out[k].astype(jnp.float32)
        out[k] = (x - means[k]) / jnp.sqrt(sd2[k] / n + 1e-12)
    return Table(columns=out, nvalid=table.nvalid)
