"""HPTMT execution context — the BSP/loosely-synchronous execution model.

The paper (§2.2) mandates loosely-synchronous execution: every worker runs
the same program and synchronizes only at communication operators — no
central scheduler.  In JAX this is *exactly* the SPMD model: one jitted
program, sharded over a named mesh; collectives are the only sync points.

:class:`HptmtContext` mirrors ``CylonEnv(config=MPIConfig(), distributed=
True)`` from the paper's Listing 1: it owns the mesh, the flattened row
axis used for table operators, and factory helpers for shard_map-based
distributed operators.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# --------------------------------------------------------------------------
# shard_map version shim
# --------------------------------------------------------------------------
# ``jax.shard_map`` (with the ``check_vma`` kwarg) only exists on newer jax
# releases; older ones expose ``jax.experimental.shard_map.shard_map`` (with
# the ``check_rep`` kwarg).  This is the single place the repo adapts to
# that API drift — import :func:`shard_map` from here, never from jax
# directly.


def _resolve_shard_map():
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm, "check_vma"
    from jax.experimental.shard_map import shard_map as sm
    return sm, "check_rep"


_SHARD_MAP, _CHECK_KW = _resolve_shard_map()


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check: bool = False) -> Callable:
    """Version-portable ``shard_map`` (replication checking off by default:
    table ops return per-shard results on purpose)."""
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})


@dataclasses.dataclass(frozen=True)
class HptmtContext:
    """Execution context binding table/tensor operators to a mesh.

    ``row_axes`` — mesh axes across which table rows are decomposed
    (the paper's row decomposition; usually ``("pod","data")`` or
    ``("data",)``).  ``world_size`` is their product — the number of
    table partitions (= paper's "parallelism").
    """

    mesh: Mesh
    row_axes: tuple[str, ...] = ("data",)

    @property
    def world_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.row_axes]))

    @property
    def rows_spec(self) -> P:
        return P(self.row_axes)

    def table_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.rows_spec)

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # -- rank of the current shard inside shard_map ----------------------
    def axis_index(self):
        idx = jax.lax.axis_index(self.row_axes[0])
        for a in self.row_axes[1:]:
            idx = idx * self.mesh.shape[a] + jax.lax.axis_index(a)
        return idx


def make_context(mesh: Mesh | None = None,
                 row_axes: Sequence[str] | None = None) -> HptmtContext:
    if mesh is None:
        dev = np.array(jax.devices())
        mesh = Mesh(dev, ("data",))
    if row_axes is None:
        row_axes = ("data",) if "data" in mesh.axis_names else \
            (mesh.axis_names[0],)
    return HptmtContext(mesh=mesh, row_axes=tuple(row_axes))
