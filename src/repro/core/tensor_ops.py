"""Distributed tensor/matrix operators (paper Tables 3–5, tensor column).

The paper's Table 5 examples:
* vector addition  -> ``AllReduce`` with SUM  (:func:`allreduce_sum`)
* matrix multiply  -> communication + local multiply
  (:func:`matmul_rowsharded`, :func:`matmul_allgather`)

plus the Horovod-style compressed gradient collectives (§3.3.1 "Horovod
provides a compression algorithm ... for distributed communication"):
:func:`quantized_psum` implements an int8 reduce-scatter/all-gather
allreduce with per-chunk scales (wire bytes ~ 1/4 of fp32).  Error
feedback lives in ``repro.optim.compression``.

All functions run inside ``shard_map``.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def allreduce_sum(x, axes):
    return jax.lax.psum(x, axes)


def allreduce_mean(x, axes):
    return jax.lax.pmean(x, axes)


def matmul_rowsharded(a_local, b_replicated):
    """A row-sharded (m/W, k) x B replicated (k, n) -> C row-sharded.

    Pleasingly parallel (no communication) — the paper's 'local operator'
    case."""
    return a_local @ b_replicated


def matmul_allgather(a_local, b_colsharded, axes):
    """A row-sharded (m/W, k) x B col-sharded (k, n/W) -> C row-sharded
    (m/W, n): all_gather B then local multiply (comm ∘ local)."""
    b = jax.lax.all_gather(b_colsharded, axes, axis=1, tiled=True)
    return a_local @ b


def _world(axes, mesh_shape) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    w = 1
    for a in axes:
        w *= mesh_shape[a]
    return w


def quantized_psum(x: jax.Array, axes, world: int, bits: int = 8):
    """Allreduce(SUM) with int8 wire format (reduce-scatter + all-gather).

    Each device: flatten -> pad to world chunks -> per-chunk symmetric int8
    quantization -> all_to_all (int8) + scales (fp32, world floats) ->
    dequantize + local sum -> re-quantize own chunk -> all_gather.

    Compression error is deterministic and identical on all devices; pair
    with error feedback (repro.optim.compression) to keep training unbiased.
    """
    assert bits == 8, "int8 is the implemented wire format"
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    chunk = -(-n // world)
    flat = jnp.pad(flat, (0, world * chunk - n))
    parts = flat.reshape(world, chunk)

    scale = jnp.max(jnp.abs(parts), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(parts / scale), -127, 127).astype(jnp.int8)

    a2a = lambda v: jax.lax.all_to_all(v, axes, split_axis=0,
                                       concat_axis=0, tiled=True)
    q_r = a2a(q)                                   # (world, chunk) int8
    s_r = a2a(scale)                               # (world, 1) fp32
    mine = jnp.sum(q_r.astype(jnp.float32) * s_r, axis=0)   # (chunk,)

    s2 = jnp.maximum(jnp.max(jnp.abs(mine)) / 127.0, 1e-30)
    q2 = jnp.clip(jnp.round(mine / s2), -127, 127).astype(jnp.int8)
    gq = jax.lax.all_gather(q2, axes, tiled=True)            # (world*chunk,)
    gs = jax.lax.all_gather(s2, axes)                        # (world,)
    out = (gq.reshape(world, chunk).astype(jnp.float32)
           * gs.reshape(world, 1)).reshape(-1)[:n]
    return out.reshape(orig_shape).astype(orig_dtype)


def psum_pytree(tree, axes):
    return jax.tree_util.tree_map(lambda v: jax.lax.psum(v, axes), tree)


def quantized_psum_pytree(tree, axes, world: int):
    return jax.tree_util.tree_map(
        lambda v: quantized_psum(v, axes, world), tree)
