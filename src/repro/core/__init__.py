"""HPTMT core: Table abstraction + local & distributed operators.

This is the paper's primary contribution realized in JAX: columnar tables
with static capacity (table.py), the paper's Table-2 local operators
(local_ops.py), and the Table-4/5 distributed operators -- communication
composed with local operators under the BSP execution model
(dist_ops.py + context.py).  Out-of-core, morsel-driven chunked
execution over the same operators lives in morsel.py.
"""
from .table import Table, INT_NULL, FLOAT_NULL  # noqa: F401
from .context import HptmtContext, make_context  # noqa: F401
from . import local_ops  # noqa: F401
from . import dist_ops  # noqa: F401
from . import morsel  # noqa: F401
