"""HPTMT Table abstraction.

The paper's data-engineering side is built on Arrow-style columnar tables
(Cylon).  On TPU we need *static shapes*, so a :class:`Table` is a
struct-of-columns where every column is a fixed-``capacity`` 1-D ``jnp``
array and ``nvalid`` (a traced scalar) says how many leading rows are live.

Representation invariants
-------------------------
* every column has shape ``(capacity,)`` and the same capacity;
* valid rows are **compacted to the front**: rows ``[0, nvalid)`` are live,
  rows ``[nvalid, capacity)`` are padding (arbitrary values);
* nulls inside live rows are encoded with sentinels (`INT_NULL`, NaN) the
  way Arrow uses validity bitmaps — see :func:`isnull`.

``Table`` is registered as a JAX pytree, so tables flow through ``jit``,
``shard_map``, ``scan`` and can be donated/sharded like any other value.
Strings are dictionary-encoded to int32 ids *before* entering the engine
(TPUs have no string type; Arrow dictionary encoding is the standard
equivalent) — see ``repro.data.dictionary``.

Column dtype contract
---------------------
The engine stores exactly two column dtypes (the TPU-native 32-bit
lanes): **int32** for integer/bool columns and **float32** for float
columns.  Ingestion (:meth:`Table.from_dict`,
``dist_ops.distribute_table``, ``morsel.ChunkedTable``) narrows wider
inputs through :func:`narrow_column`:

* ``float64 -> float32`` silently (precision loss only, ordering and
  equality of representable values survive);
* integer values **must fit int32** — out-of-range values *raise*
  instead of truncating.  Truncation is not a precision issue: two
  distinct int64 keys 2^32 apart alias to the same int32 bits, which
  turns into *false join matches* downstream.  Callers with wider keys
  dictionary-encode them first (``repro.data.dictionary``), same as
  strings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

INT_NULL = np.iinfo(np.int32).min
FLOAT_NULL = np.nan


def narrow_column(name: str, v: np.ndarray) -> np.ndarray:
    """Narrow an ingested numpy column to the engine dtype contract
    (int32 / float32 — see the module docstring).

    Floats narrow silently; integer/bool values outside the int32 range
    raise ``ValueError`` instead of truncating (aliased key bits make
    false join matches, never a recoverable precision loss)."""
    if np.issubdtype(v.dtype, np.floating):
        return v.astype(np.float32)
    if np.issubdtype(v.dtype, np.integer) or v.dtype == np.bool_:
        if v.dtype != np.int32 and v.size:
            info = np.iinfo(np.int32)
            lo, hi = v.min(), v.max()
            if lo < info.min or hi > info.max:
                raise ValueError(
                    f"column {name!r} ({v.dtype}) has values in "
                    f"[{lo}, {hi}] outside the int32 range "
                    f"[{info.min}, {info.max}]; refusing to truncate "
                    "(aliased keys make false join matches) — "
                    "dictionary-encode wide keys first "
                    "(repro.data.dictionary)")
        return v.astype(np.int32)
    raise TypeError(
        f"column {name!r} dtype {v.dtype} unsupported; dictionary-"
        "encode strings first (repro.data.dictionary)")


def _is_float(x) -> bool:
    return jnp.issubdtype(x.dtype, jnp.floating)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Table:
    """Columnar table with static capacity and a dynamic valid-row count."""

    columns: dict[str, jax.Array]          # name -> (capacity,) array
    nvalid: jax.Array                      # int32 scalar

    # ---------------------------------------------------------------- pytree
    def tree_flatten(self):
        names = tuple(self.columns.keys())
        children = tuple(self.columns[n] for n in names) + (self.nvalid,)
        return children, names

    @classmethod
    def tree_unflatten(cls, names, children):
        cols = dict(zip(names, children[:-1]))
        return cls(columns=cols, nvalid=children[-1])

    # ------------------------------------------------------------- properties
    @property
    def capacity(self) -> int:
        if not self.columns:
            return 0
        return next(iter(self.columns.values())).shape[0]

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(self.columns.keys())

    @property
    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.nvalid

    def __getitem__(self, name: str) -> jax.Array:
        return self.columns[name]

    # ------------------------------------------------------------ constructors
    @classmethod
    def from_dict(
        cls,
        data: Mapping[str, Any],
        capacity: int | None = None,
    ) -> "Table":
        """Build a table from numpy/array columns, padding to ``capacity``."""
        arrays = {k: np.asarray(v) for k, v in data.items()}
        if not arrays:
            return cls(columns={}, nvalid=jnp.int32(0))
        n = len(next(iter(arrays.values())))
        for k, v in arrays.items():
            if v.ndim != 1:
                raise ValueError(f"column {k!r} must be 1-D, got {v.shape}")
            if len(v) != n:
                raise ValueError("all columns must have equal length")
        cap = capacity if capacity is not None else max(n, 1)
        if cap < n:
            raise ValueError(f"capacity {cap} < number of rows {n}")
        cols = {}
        for k, v in arrays.items():
            v = narrow_column(k, v)
            pad = np.zeros(cap - n, v.dtype)
            cols[k] = jnp.asarray(np.concatenate([v, pad]))
        return cls(columns=cols, nvalid=jnp.int32(n))

    def to_numpy(self) -> dict[str, np.ndarray]:
        """Materialize only the valid rows (host-side, non-jittable)."""
        n = int(self.nvalid)
        return {k: np.asarray(v)[:n] for k, v in self.columns.items()}

    def to_tensor(self, names: Sequence[str] | None = None) -> jax.Array:
        """Stage-3 of the paper: Table -> dense feature tensor.

        Returns a ``(capacity, n_cols)`` float32 tensor (padding rows are
        zeroed) — the hand-off from data engineering to data analytics.
        """
        names = list(names) if names is not None else list(self.names)
        mask = self.valid_mask
        cols = [
            jnp.where(mask, self.columns[n].astype(jnp.float32), 0.0)
            for n in names
        ]
        return jnp.stack(cols, axis=1)

    # ---------------------------------------------------------------- helpers
    def replace_columns(self, columns: dict[str, jax.Array]) -> "Table":
        return Table(columns=columns, nvalid=self.nvalid)

    def with_nvalid(self, nvalid) -> "Table":
        return Table(columns=dict(self.columns),
                     nvalid=jnp.asarray(nvalid, jnp.int32))

    def gather_rows(self, idx: jax.Array, nvalid) -> "Table":
        """New table whose row ``i`` is this table's row ``idx[i]``."""
        cols = {k: v[idx] for k, v in self.columns.items()}
        return Table(columns=cols, nvalid=jnp.asarray(nvalid, jnp.int32))

    def pad_to(self, capacity: int) -> "Table":
        """Grow capacity (no-op if already >=)."""
        cap = self.capacity
        if capacity < cap:
            raise ValueError("pad_to cannot shrink; use head()")
        if capacity == cap:
            return self
        cols = {
            k: jnp.concatenate(
                [v, jnp.zeros((capacity - cap,), v.dtype)])
            for k, v in self.columns.items()
        }
        return Table(columns=cols, nvalid=self.nvalid)

    def rename(self, mapping: Mapping[str, str]) -> "Table":
        cols = {mapping.get(k, k): v for k, v in self.columns.items()}
        return Table(columns=cols, nvalid=self.nvalid)

    def add_prefix(self, prefix: str) -> "Table":
        return Table(columns={prefix + k: v for k, v in self.columns.items()},
                     nvalid=self.nvalid)

    def astype(self, dtypes: Mapping[str, Any]) -> "Table":
        cols = dict(self.columns)
        for k, dt in dtypes.items():
            cols[k] = cols[k].astype(dt)
        return Table(columns=cols, nvalid=self.nvalid)

    def map_column(self, name: str, fn: Callable[[jax.Array], jax.Array],
                   out: str | None = None) -> "Table":
        cols = dict(self.columns)
        cols[out or name] = fn(cols[name])
        return Table(columns=cols, nvalid=self.nvalid)


def null_like(col: jax.Array) -> jax.Array:
    """A column of nulls with the same shape/dtype."""
    if _is_float(col):
        return jnp.full_like(col, FLOAT_NULL)
    return jnp.full_like(col, INT_NULL)


def isnull_values(col: jax.Array) -> jax.Array:
    if _is_float(col):
        return jnp.isnan(col)
    return col == INT_NULL
