"""Out-of-core, morsel-driven execution over the distributed operators.

Every bench in ``results/bench.json`` used to top out near 200k rows
because a table had to fit device memory in one piece.  This module
removes that ceiling the way the paper's predecessor systems do (Cylon's
streaming shuffle, linear-dag's blockwise operators over HDF5): the
*operator contract* — communication ∘ local operator with counted
overflow — is the unit of scalability, not the materialized table.  Host
memory (or a memory-mapped file: ``np.memmap`` columns work unchanged)
holds the full relation; the device only ever holds one fixed-capacity
**morsel** per side plus the operator's resident state.

Execution model
---------------
:class:`ChunkedTable` is the host-side source: numpy columns cut into
fixed-``chunk_rows`` morsels, each streamed through
:func:`~repro.core.dist_ops.distribute_table` (same dtype contract:
floats narrow to float32, out-of-int32-range integers raise).  Each
chunked operator builds its per-chunk step as a *kwarg-free*
:class:`~repro.core.dist_ops.DistributedPipeline`, so the whole chunk
loop re-enters one compiled XLA program — the per-morsel cost is
execution + host↔device copies, never re-tracing:

``chunked_dist_join``
    The **build** side is hash-shuffled once and kept device-resident
    per shard (accumulated through :func:`local_ops.append_rows` when the
    build side itself arrives in chunks); the **probe** side streams:
    shuffle each probe morsel on the key, local-join it against the
    resident build shard, collect the output morsel to the host.  Equal
    keys co-locate under the same partition hash for every chunk, so
    per-chunk joins compose to the exact global join.  With
    ``build='restream'`` neither side is resident: each probe morsel is
    shuffled once, then joined against every (re-shuffled) build morsel
    — inner joins only, since an inner join distributes over build
    partition while a left join does not.

``chunked_dist_groupby``
    Per morsel: shuffle on the keys + local *partial* aggregation
    (``mean`` decomposes into sum+count, see
    :func:`local_ops.partial_agg_columns`), then fold into a
    device-resident accumulator table with
    :func:`local_ops.merge_partial_aggregates` — the merge re-runs the
    pluggable aggregation backend (the existing hash-groupby slabs)
    over accumulator + partial, so it stays canonical (key-sorted) and
    associative.  A final device-side pass maps partials to the
    requested aggregates (``mean = sum / max(count, 1)``) — identical
    to the monolithic formula, so results are bit-identical whenever
    float addition is exact (integer-valued data), and agree to
    addition-order rounding otherwise.

``chunked_dist_sort``
    Per morsel: a full :func:`~repro.core.dist_ops.dist_sort`
    (sample-sort) producing one globally-sorted *run* on the host; runs
    then fold through a stable vectorized k-way merge (adjacent pairwise
    merges, earlier chunks win ties).  Because the monolithic sample
    sort's equal keys also tie in original row order, the chunked result
    is bit-identical to the monolithic one, ties included.

Overflow contract
-----------------
Every stage keeps the engine's "dropped, never silently lost" rule: the
per-chunk shuffle, local-operator, append, and merge counters are
psum'd on device and **summed across chunks** on the host — each
operator returns ``(result, total_dropped)`` and callers size
capacities so the total stays zero.
"""
from __future__ import annotations

import math
from typing import Callable, Mapping, Sequence

import jax
import numpy as np

from . import dist_ops as D
from . import local_ops as L
from .context import HptmtContext
from .table import narrow_column

__all__ = [
    "ChunkedTable",
    "chunked_dist_join",
    "chunked_dist_groupby",
    "chunked_dist_sort",
    "merge_sorted_runs",
]


class ChunkedTable:
    """Host-side chunked table: numpy columns streamed as fixed-size
    morsels.

    ``data`` maps column name -> 1-D numpy array (all equal length; a
    ``np.memmap`` works — chunks are slices, nothing is copied until a
    chunk is distributed).  ``chunk_rows`` is the morsel size: every
    chunk has exactly ``chunk_rows`` rows except the last (and a
    zero-row table yields exactly one empty chunk — the terminal-morsel
    shape the operators must handle).
    """

    def __init__(self, data: Mapping[str, np.ndarray], chunk_rows: int):
        if chunk_rows <= 0:
            raise ValueError(f"chunk_rows must be positive, got "
                             f"{chunk_rows}")
        self.columns = {k: np.asarray(v) for k, v in data.items()}
        if not self.columns:
            raise ValueError("ChunkedTable needs at least one column")
        lengths = {k: len(v) for k, v in self.columns.items()}
        if len(set(lengths.values())) != 1:
            raise ValueError(f"columns must have equal length: {lengths}")
        self.nrows = next(iter(lengths.values()))
        self.chunk_rows = int(chunk_rows)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(self.columns.keys())

    @property
    def num_chunks(self) -> int:
        return max(1, math.ceil(self.nrows / self.chunk_rows))

    def chunk(self, i: int) -> dict[str, np.ndarray]:
        lo = i * self.chunk_rows
        hi = min(lo + self.chunk_rows, self.nrows)
        return {k: v[lo:hi] for k, v in self.columns.items()}

    def chunks(self):
        for i in range(self.num_chunks):
            yield self.chunk(i)

    def capacity_per_shard(self, world: int) -> int:
        """The fixed per-shard device capacity one morsel needs — the
        same for every chunk (the last, smaller chunk reuses it so the
        jitted step sees one shape)."""
        return max(1, math.ceil(self.chunk_rows / world))

    def distribute(self, ctx: HptmtContext,
                   capacity_per_shard: int | None = None):
        """Stream the chunks through ``distribute_table``: yields one
        global row-sharded Table per morsel, all with the same static
        capacity."""
        cap = capacity_per_shard or self.capacity_per_shard(ctx.world_size)
        for chunk in self.chunks():
            yield D.distribute_table(ctx, chunk, capacity_per_shard=cap)


def _as_chunked(data, default_chunk_rows: int | None = None):
    if isinstance(data, ChunkedTable):
        return data
    n = len(next(iter(data.values())))
    return ChunkedTable(data, default_chunk_rows or max(n, 1))


def _dropped(d) -> int:
    """Host-side value of a pipeline's psum'd (replicated) drop counter."""
    a = np.asarray(d)
    return int(a.max()) if a.size else 0


def _emit(parts: list, sink, out: dict):
    if sink is not None:
        sink(out)
    else:
        parts.append(out)


def _concat_parts(parts: list[dict] | None):
    if parts is None:
        return None
    cols: dict[str, list] = {}
    for p in parts:
        for k, v in p.items():
            cols.setdefault(k, []).append(v)
    return {k: np.concatenate(v) for k, v in cols.items()}


# --------------------------------------------------------------------------
# Chunked distributed join
# --------------------------------------------------------------------------


def chunked_dist_join(ctx: HptmtContext, left, right, *,
                      left_on: Sequence[str],
                      right_on: Sequence[str] | None = None,
                      how: str = "inner",
                      build: str = "resident",
                      out_capacity_per_shard: int | None = None,
                      build_capacity_per_shard: int | None = None,
                      overcommit: float = 2.0,
                      local_impl: str | None = None,
                      local_join_sizes: Mapping[str, int] | None = None,
                      sink: Callable[[dict], None] | None = None):
    """Morsel-driven distributed join: stream the probe (left) side in
    chunks against a build (right) side, past-device-memory sized.

    ``left`` / ``right`` are :class:`ChunkedTable` or plain column
    mappings.  ``build='resident'`` (default): the right side is
    shuffled once into a device-resident per-shard build table of
    capacity ``build_capacity_per_shard`` (default: rows-per-shard x
    ``overcommit`` for partition-imbalance headroom) — supports
    ``how='inner'|'left'``.  ``build='restream'``: the right side is
    re-streamed per probe morsel (block-nested loop; inner joins only —
    a left join does not distribute over build partition).

    ``out_capacity_per_shard`` bounds one morsel's join output per shard
    (default: the shuffled probe-morsel capacity — size it up for
    multiplicative keys).  Returns ``(columns, dropped)`` with
    ``columns`` the host-side numpy result (chunk-major, shard-major
    within a chunk — chunk boundaries permute row order exactly like
    shard boundaries already do; content is bit-identical to the
    monolithic ``dist_join``) and ``dropped`` the overflow total across
    every chunk's shuffle + local join (+ build append).  When ``sink``
    is given each output morsel is handed to it instead and ``columns``
    is None.
    """
    if how not in ("inner", "left"):
        raise ValueError("how must be 'inner' or 'left'")
    if build not in ("resident", "restream"):
        raise ValueError("build must be 'resident' or 'restream'")
    if build == "restream" and how != "inner":
        raise ValueError("build='restream' supports inner joins only: a "
                         "left join does not distribute over build "
                         "partition (unmatched rows would duplicate "
                         "per build morsel)")
    left_on = list(left_on)
    right_on = list(right_on) if right_on is not None else list(left_on)
    left = _as_chunked(left)
    right = _as_chunked(right)
    world = ctx.world_size
    pcap = left.capacity_per_shard(world)
    _, ploc = D.default_shuffle_sizes(ctx, pcap, overcommit)
    out_cap = out_capacity_per_shard or ploc
    sizes = dict(local_join_sizes or {})
    dropped = 0
    parts: list[dict] | None = None if sink is not None else []

    if build == "resident":
        bcap = build_capacity_per_shard or max(
            1, math.ceil(right.nrows / world * overcommit))
        acc = D.distribute_table(
            ctx, {k: narrow_column(k, v[:0]) for k, v in
                  right.columns.items()},
            capacity_per_shard=bcap)

        def build_step(c, a, chunk):
            sh, d = D.shuffle(c, chunk, right_on, overcommit=overcommit)
            a2, ad = L.append_rows(a, sh)
            return a2, d + jax.lax.psum(ad, c.row_axes)

        # donate the accumulator (rebound each iteration) so the append
        # folds in place — the per-chunk morsel's buffers match no output
        # shape (the shuffle slab is overcommitted), so donating it would
        # be a no-op
        build_pipe = D.DistributedPipeline(ctx, build_step,
                                           donate_argnums=(0,))
        for g in right.distribute(ctx):
            acc, d = build_pipe(acc, g)
            dropped += _dropped(d)

        def probe_step(c, b, chunk):
            sh, d = D.shuffle(c, chunk, left_on, overcommit=overcommit)
            out, jd = L.join(sh, b, left_on=left_on, right_on=right_on,
                             how=how, out_capacity=out_cap,
                             impl=local_impl, return_overflow=True,
                             **sizes)
            return out, d + jax.lax.psum(jd, c.row_axes)

        # no donation: the resident build side (arg 0) is read again on
        # every subsequent chunk, and the probe morsel's buffers match no
        # output shape (join output is sized out_cap, not the morsel cap)
        probe_pipe = D.DistributedPipeline(ctx, probe_step)
        for g in left.distribute(ctx):
            out, d = probe_pipe(acc, g)
            dropped += _dropped(d)
            _emit(parts, sink, D.collect_table(ctx, out))
        return _concat_parts(parts), dropped

    # restream: block-nested loop — shuffle each probe morsel once, join
    # it against every (re-shuffled) build morsel; inner joins are
    # additive over build partition, so the emitted morsels compose.
    shuffle_probe = D.DistributedPipeline(
        ctx, lambda c, t: D.shuffle(c, t, left_on, overcommit=overcommit))
    shuffle_build = D.DistributedPipeline(
        ctx, lambda c, t: D.shuffle(c, t, right_on, overcommit=overcommit))

    def join_step(c, l, r):
        out, jd = L.join(l, r, left_on=left_on, right_on=right_on,
                         how="inner", out_capacity=out_cap,
                         impl=local_impl, return_overflow=True, **sizes)
        return out, jax.lax.psum(jd, c.row_axes)

    # no donation: the shuffled probe morsel (arg 0) is re-joined against
    # every build morsel, and the build morsel's buffers only match the
    # join output's shapes by coincidence of chunk sizing
    join_pipe = D.DistributedPipeline(ctx, join_step)
    for pg in left.distribute(ctx):
        psh, d = shuffle_probe(pg)
        dropped += _dropped(d)
        for bg in right.distribute(ctx):
            bsh, d = shuffle_build(bg)
            dropped += _dropped(d)
            out, d = join_pipe(psh, bsh)
            dropped += _dropped(d)
            _emit(parts, sink, D.collect_table(ctx, out))
    return _concat_parts(parts), dropped


# --------------------------------------------------------------------------
# Chunked distributed groupby (partial aggregates + associative merge)
# --------------------------------------------------------------------------


def chunked_dist_groupby(ctx: HptmtContext, table, by: Sequence[str],
                         aggs: Mapping[str, Sequence[str] | str], *,
                         group_capacity_per_shard: int | None = None,
                         overcommit: float = 2.0,
                         local_impl: str | None = None,
                         groupby_sizes: Mapping[str, int] | None = None):
    """Morsel-driven distributed GroupBy+Aggregate.

    Streams ``table`` (a :class:`ChunkedTable` or column mapping) chunk
    by chunk: shuffle on the keys, local *partial* aggregation, and an
    associative :func:`local_ops.merge_partial_aggregates` fold into a
    device-resident accumulator of ``group_capacity_per_shard`` groups
    per shard (default: the shuffled-morsel capacity — size it to the
    expected per-shard distinct-key count; overflowing *groups* are
    dropped and counted, never silently lost).  A key is pinned to one
    shard by the partition hash, so the final accumulator equals the
    monolithic ``dist_groupby`` result per shard — bit-identically when
    float addition is exact (see the module docstring).

    Returns ``(columns, dropped)``: the host-collected canonical result
    (one row per key, key-sorted within its shard) and the chunk-summed
    overflow total.
    """
    by = list(by)
    aggs_norm = {c: [ops] if isinstance(ops, str) else list(ops)
                 for c, ops in aggs.items()}
    partials = L.partial_agg_columns(aggs_norm)
    table = _as_chunked(table)
    world = ctx.world_size
    cap = table.capacity_per_shard(world)
    _, oc = D.default_shuffle_sizes(ctx, cap, overcommit)
    gcap = group_capacity_per_shard or oc
    sizes = dict(groupby_sizes or {})

    acc0 = {k: narrow_column(k, table.columns[k][:0]) for k in by}
    for col, ops in partials.items():
        for op in ops:
            dt = np.int32 if op == "count" else np.float32
            acc0[f"{col}_{op}"] = np.zeros(0, dt)
    acc = D.distribute_table(ctx, acc0, capacity_per_shard=gcap)

    def step(c, a, chunk):
        sh, d1 = D.shuffle(c, chunk, by, overcommit=overcommit)
        part, d2 = L.groupby_aggregate(sh, by, partials, impl=local_impl,
                                       return_overflow=True, **sizes)
        merged, d3 = L.merge_partial_aggregates(a, part, by,
                                                impl=local_impl, **sizes,
                                                return_overflow=True)
        return merged, d1 + jax.lax.psum(d2 + d3, c.row_axes)

    # donate the accumulator (rebound each fold — merge keeps its
    # capacity, so XLA folds the merge in place)
    pipe = D.DistributedPipeline(ctx, step, donate_argnums=(0,))
    dropped = 0
    for g in table.distribute(ctx):
        acc, d = pipe(acc, g)
        dropped += _dropped(d)

    def finalize(c, a):
        cols = {k: a.columns[k] for k in by}
        for col, ops in aggs_norm.items():
            for op in ops:
                if op == "mean":
                    cnt = a.columns[f"{col}_count"]
                    v = a.columns[f"{col}_sum"] / \
                        jax.numpy.maximum(cnt, 1).astype(jax.numpy.float32)
                else:
                    v = a.columns[f"{col}_{op}"]
                cols[f"{col}_{op}"] = v
        return L.Table(columns=cols, nvalid=a.nvalid)

    out = D.DistributedPipeline(ctx, finalize)(acc)
    return D.collect_table(ctx, out), dropped


# --------------------------------------------------------------------------
# Chunked distributed sort (sorted runs + stable host k-way merge)
# --------------------------------------------------------------------------


def _np_sort_key(col: np.ndarray, ascending: bool) -> np.ndarray:
    """Host mirror of ``local_ops._sort_key`` (order-reversal transform)."""
    if ascending:
        return col
    if np.issubdtype(col.dtype, np.floating):
        return -col
    return ~col


def _np_tuple_less(a: tuple, b: tuple) -> np.ndarray:
    res = np.zeros(a[0].shape, bool)
    eq = np.ones(a[0].shape, bool)
    for x, y in zip(a, b):
        res = res | (eq & (x < y))
        eq = eq & (x == y)
    return res


def _np_lex_searchsorted(sorted_keys: tuple, query_keys: tuple,
                         side: str) -> np.ndarray:
    """Host mirror of ``local_ops.lex_searchsorted`` (vectorized binary
    search over parallel lexicographically-sorted key columns)."""
    n = len(sorted_keys[0]) if sorted_keys else 0
    m = len(query_keys[0]) if query_keys else 0
    lo = np.zeros(m, np.int64)
    hi = np.full(m, n, np.int64)
    iters = max(1, int(n - 1).bit_length() + 1) if n > 0 else 0
    for _ in range(iters):
        mid = (lo + hi) // 2
        midc = np.clip(mid, 0, max(n - 1, 0))
        at_mid = tuple(k[midc] for k in sorted_keys)
        if side == "left":
            go_right = _np_tuple_less(at_mid, query_keys)
        else:
            go_right = ~_np_tuple_less(query_keys, at_mid)
        go_right = go_right & (mid < hi)
        lo = np.where(go_right, mid + 1, lo)
        hi = np.where(go_right, hi, mid)
    return lo


def _merge_two_runs(a: dict, b: dict, by: list, ascending: bool) -> dict:
    ak = tuple(_np_sort_key(a[k], ascending) for k in by)
    bk = tuple(_np_sort_key(b[k], ascending) for k in by)
    n, m = len(ak[0]), len(bk[0])
    # stable positions: a row i lands at i + |b rows strictly less|,
    # b row j at j + |a rows less-or-equal| — a (the earlier run) wins ties
    pos_a = np.arange(n) + _np_lex_searchsorted(bk, ak, "left")
    pos_b = np.arange(m) + _np_lex_searchsorted(ak, bk, "right")
    out = {}
    for k in a:
        col = np.empty(n + m, a[k].dtype)
        col[pos_a] = a[k]
        col[pos_b] = b[k]
        out[k] = col
    return out


def merge_sorted_runs(runs: list[dict], by: Sequence[str],
                      ascending: bool = True) -> dict:
    """Stable k-way merge of sorted runs (host-side, vectorized).

    Adjacent pairwise merges keep run order, so ties resolve to the
    earlier run — matching the monolithic sample sort's original-row
    tie order when runs are consecutive chunks."""
    by = list(by)
    if not runs:
        return {}
    runs = list(runs)
    while len(runs) > 1:
        nxt = []
        for i in range(0, len(runs) - 1, 2):
            nxt.append(_merge_two_runs(runs[i], runs[i + 1], by, ascending))
        if len(runs) % 2:
            nxt.append(runs[-1])
        runs = nxt
    return runs[0]


def chunked_dist_sort(ctx: HptmtContext, table, by: Sequence[str],
                      ascending: bool = True, *,
                      n_samples: int = 32, overcommit: float = 2.0,
                      local_impl: str | None = None):
    """Morsel-driven distributed OrderBy: each chunk runs the full
    sample sort (``dist_sort``) into a globally-sorted host run; runs
    fold through the stable k-way merge.  Bit-identical to the
    monolithic ``dist_sort`` — equal keys tie in original row order both
    ways.  Returns ``(columns, dropped)``.
    """
    by = list(by)
    table = _as_chunked(table)
    pipe = D.DistributedPipeline(
        ctx, lambda c, t: D.dist_sort(c, t, by, ascending=ascending,
                                      n_samples=n_samples,
                                      overcommit=overcommit,
                                      local_impl=local_impl))
    runs, dropped = [], 0
    for g in table.distribute(ctx):
        out, d = pipe(g)
        dropped += _dropped(d)
        runs.append(D.collect_table(ctx, out))
    return merge_sorted_runs(runs, by, ascending), dropped
