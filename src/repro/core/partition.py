"""Hash partitioning for table shuffles (Cylon's hash-partition step).

Key hashing uses a murmur3-style 32-bit finalizer (the same family Cylon /
Arrow use) combined across key columns; partition id = hash % P.  The
histogram/rank hot loop is the ``kernels/hash_partition`` Pallas kernel
(pure-jnp ref on CPU).
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from ..kernels.hash_partition import partition_plan
from .table import Table


def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 over uint32."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _col_bits(col: jnp.ndarray) -> jnp.ndarray:
    import jax
    if jnp.issubdtype(col.dtype, jnp.floating):
        # normalize -0.0 to +0.0 so equal keys hash equal
        col = jnp.where(col == 0.0, jnp.zeros_like(col), col)
        return jax.lax.bitcast_convert_type(col.astype(jnp.float32),
                                            jnp.uint32)
    return jax.lax.bitcast_convert_type(col.astype(jnp.int32), jnp.uint32)


def hash_columns(cols: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Combined 32-bit hash of parallel key columns."""
    h = jnp.full(cols[0].shape, jnp.uint32(0x9E3779B9))
    for c in cols:
        bits = _col_bits(c)
        h = _mix32(h ^ (bits + jnp.uint32(0x9E3779B9)
                        + (h << 6) + (h >> 2)))
    return h


def partition_ids(table: Table, key_cols: Sequence[str],
                  num_partitions: int) -> jnp.ndarray:
    """Partition id per row; padding rows get id 0 (callers mask them)."""
    h = hash_columns([table.columns[k] for k in key_cols])
    pid = (h % jnp.uint32(num_partitions)).astype(jnp.int32)
    return jnp.where(table.valid_mask, pid, 0)


def plan_partitions(table: Table, key_cols: Sequence[str],
                    num_partitions: int, impl: str = "ref"):
    """(hist, dest-slot) over *valid* rows only.

    Padding rows are routed to a one-past-the-end trash partition so they
    never consume real slots.
    """
    pid = partition_ids(table, key_cols, num_partitions)
    pid = jnp.where(table.valid_mask, pid, num_partitions)
    hist, dest = partition_plan(pid, num_partitions + 1, impl=impl)
    return hist[:num_partitions], dest, pid
