"""Distributed HPTMT table operators (paper §2.1.2, Tables 4–5).

Every distributed operator is *communication ∘ local operator*, exactly the
paper's recipe:

=================  =======================================================
distributed op     implementation (paper Table 5)
=================  =======================================================
shuffle            hash partition (Pallas radix kernel) + ``all_to_all``
join               shuffle both sides + local join; the local backend is
                   pluggable via ``local_impl`` — ``"sortmerge"`` (binary
                   search over sorted keys, default) or ``"hash"``
                   (bucketed Pallas build+probe, kernels/hash_join) —
                   so the distributed join runs hash-local end to end
broadcast join     ``all_gather`` small side + local join   (beyond-paper)
groupby            shuffle + local groupby-aggregate; the local backend is
                   pluggable via ``local_impl`` — ``"sort"`` (default) or
                   ``"hash"`` (bucketed Pallas hash-accumulate,
                   kernels/hash_groupby)
unique             shuffle + local drop_duplicates (under ``"hash"`` a
                   key-only hash groupby — same pluggable backend)
sort (OrderBy)     sample-sort: local sort + splitter ``all_gather`` +
                   range partition + ``all_to_all`` + local sort; the
                   local sorts are pluggable via ``local_impl`` —
                   ``"xla"`` (``lax.sort``, default) or ``"radix"``
                   (multi-pass LSD rank, kernels/radix_sort) — so the
                   distributed sort runs sort-primitive-free end to end
difference/        shuffle both sides + local set op; the local semi-join
intersect/isin     backend is pluggable via ``local_impl`` —
                   ``"sortmerge"`` (default) or ``"hash"`` (bucketed
                   membership probe, kernels/hash_semi) — so the
                   distributed set ops run hash-local end to end
repartition        global-rank range partition + ``all_to_all``
                   (straggler/skew mitigation)
=================  =======================================================

All functions here run **inside** ``jax.shard_map`` over the context's row
axes — the BSP model: every worker executes this same trace; the
collectives are the only synchronization points.  Use
:class:`DistributedPipeline` to wrap a whole pipeline in one shard_map
(one XLA program = one BSP superstep chain).

Static-shape contract: a shuffle can route at most ``slots_per_dest`` rows
from one sender to one receiver and materialize at most ``out_capacity``
rows per receiver.  Overflowing rows are dropped and *counted* (returned as
a metric) — tests and callers size capacities so overflow is zero;
production configs use ``overcommit`` headroom (default 2x).

Chunked-execution contract: tables larger than device memory run through
``core/morsel.py``, which streams fixed-capacity host-side chunks
through :func:`distribute_table` and loops them over these same
operators — join with a device-resident (or re-streamed) build side,
groupby as partial aggregates folded through
``local_ops.merge_partial_aggregates``, sort as per-chunk sample-sort
runs k-way-merged on the host.  Each chunk re-enters one cached
:class:`DistributedPipeline` program (same static shapes every morsel),
and the per-chunk overflow counters aggregate into one across-chunks
total, so the counted-overflow contract survives chunking unchanged.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from . import local_ops as L
from .context import HptmtContext, shard_map
from .kernel_backend import radix_impl
from .kernel_backend import sort_impl as _default_sort_impl
from .partition import hash_columns, partition_ids
from .table import Table, narrow_column as _narrow_column
from ..kernels import bucketing as _bucketing
from ..kernels.hash_partition import radix_histogram_ranks
from ..kernels.radix_sort import radix_permutation, stable_partition_perm

# --------------------------------------------------------------------------
# global <-> local adapters
# --------------------------------------------------------------------------


def distribute_table(ctx: HptmtContext, data: Mapping[str, np.ndarray],
                     capacity_per_shard: int | None = None) -> Table:
    """Host-side: build a *global* row-sharded Table from numpy columns.

    Rows are block-distributed over the row axes (the paper's row
    decomposition).  The global table's ``nvalid`` is a ``(world,)`` vector
    of per-shard counts.

    Columns follow the engine dtype contract (``core/table.py``):
    floats narrow to float32; integer values outside the int32 range
    *raise* instead of truncating (aliased key bits would fabricate join
    matches).  ``capacity_per_shard=None`` means rows-per-shard; an
    explicit non-positive capacity is an error, never silently coerced.
    """
    world = ctx.world_size
    arrays = {k: np.asarray(v) for k, v in data.items()}
    n = len(next(iter(arrays.values())))
    per = math.ceil(n / world) if n else 1
    if capacity_per_shard is None:
        cap = per
    else:
        if capacity_per_shard <= 0:
            raise ValueError(
                f"capacity_per_shard must be positive, got "
                f"{capacity_per_shard} (pass None for rows-per-shard)")
        cap = capacity_per_shard
    if cap < per:
        raise ValueError(f"capacity_per_shard {cap} < rows/shard {per}")
    cols, nvalid = {}, np.zeros((world,), np.int32)
    for s in range(world):
        lo, hi = min(s * per, n), min((s + 1) * per, n)
        nvalid[s] = hi - lo
    for k, v in arrays.items():
        v = _narrow_column(k, v)
        buf = np.zeros((world, cap), v.dtype)
        for s in range(world):
            lo, hi = min(s * per, n), min((s + 1) * per, n)
            buf[s, : hi - lo] = v[lo:hi]
        cols[k] = jax.device_put(
            buf.reshape(world * cap),
            NamedSharding(ctx.mesh, ctx.rows_spec))
    nvalid = jax.device_put(jnp.asarray(nvalid),
                            NamedSharding(ctx.mesh, ctx.rows_spec))
    return Table(columns=cols, nvalid=nvalid)


def collect_table(ctx: HptmtContext, table: Table) -> dict[str, np.ndarray]:
    """Host-side: gather a global row-sharded Table back to numpy (valid
    rows only, shard order preserved)."""
    world = ctx.world_size
    nvalid = np.asarray(table.nvalid).reshape(world)
    out = {}
    for k, v in table.columns.items():
        v = np.asarray(v).reshape(world, -1)
        out[k] = np.concatenate([v[s, : nvalid[s]] for s in range(world)])
    return out


def _to_local(table: Table) -> Table:
    """Inside shard_map: nvalid arrives as shape (1,), squeeze to scalar."""
    return Table(columns=dict(table.columns),
                 nvalid=table.nvalid.reshape(()))


def _to_global(table: Table) -> Table:
    return Table(columns=dict(table.columns),
                 nvalid=table.nvalid.reshape((1,)))


# --------------------------------------------------------------------------
# The shuffle — HPTMT's Table communication operator (paper Table 4)
# --------------------------------------------------------------------------


def shuffle_by_pid(ctx: HptmtContext, table: Table, pid: jnp.ndarray,
                   slots_per_dest: int, out_capacity: int):
    """Route each valid row to shard ``pid[row]`` via one ``all_to_all``.

    Returns ``(table, dropped)`` where ``dropped`` counts rows lost to the
    static ``slots_per_dest``/``out_capacity`` bounds (0 when sized right).
    """
    world = ctx.world_size
    valid = table.valid_mask
    names = table.names
    # trash partition `world` for padding rows
    pid = jnp.where(valid, pid, world)
    hist, ranks = radix_histogram_ranks(pid, world + 1, impl=radix_impl())
    ok = valid & (ranks < slots_per_dest) & (pid < world)
    flat = jnp.where(ok, pid * slots_per_dest + ranks,
                     world * slots_per_dest)
    nslots = world * slots_per_dest

    # send side: every column (bitcast to an int32 plane) plus the
    # occupancy plane land in the (ncols+1, nslots) send slabs via ONE
    # stacked scatter — not one scatter per column.
    planes = [_bucketing.pack_i32(table.columns[n]) for n in names] \
        + [ok.astype(jnp.int32)]
    stacked = jnp.stack(planes)                     # (ncols+1, cap)
    send = (jnp.zeros((len(planes), nslots + 1), jnp.int32)
            .at[:, flat].set(stacked)[:, :nslots]
            .reshape(len(planes), world, slots_per_dest))
    # ONE all_to_all moves all columns together: block d of axis 1 goes
    # to shard d, so per (column, destination) the payload is exactly the
    # old per-column transfer.
    recv = jax.lax.all_to_all(send, ctx.row_axes, split_axis=1,
                              concat_axis=1, tiled=True) \
        .reshape(len(planes), nslots)
    recv_valid = recv[-1] > 0
    n_recv = jnp.sum(recv_valid, dtype=jnp.int32)
    # receive side: write the all_to_all output straight into the
    # out_capacity slabs with one stacked scatter — each valid row's slot
    # is its rank among valid rows in slot order (cumsum), which is
    # bit-identical to the stable-partition + gather compaction it
    # replaces, without materializing the intermediate table.
    pos = jnp.cumsum(recv_valid.astype(jnp.int32)) - 1
    okr = recv_valid & (pos < out_capacity)
    dest = jnp.where(okr, pos, out_capacity)
    out = (jnp.zeros((len(names), out_capacity + 1), jnp.int32)
           .at[:, dest].set(recv[:-1])[:, :out_capacity])
    cols = {n: _bucketing.unpack_i32(out[i], table.columns[n].dtype)
            for i, n in enumerate(names)}
    compacted = Table(columns=cols,
                      nvalid=jnp.minimum(n_recv, out_capacity))
    sent_dropped = jnp.sum(
        jnp.maximum(hist[:world] - slots_per_dest, 0), dtype=jnp.int32)
    recv_dropped = jnp.maximum(n_recv - out_capacity, 0)
    dropped = jax.lax.psum(sent_dropped, ctx.row_axes) + \
        jax.lax.psum(recv_dropped, ctx.row_axes)
    return compacted, dropped


def default_shuffle_sizes(ctx: HptmtContext, capacity: int,
                          overcommit: float = 2.0):
    world = ctx.world_size
    slots = max(1, math.ceil(capacity * overcommit / world))
    out_cap = max(capacity, math.ceil(capacity * overcommit))
    return slots, out_cap


def _pad8(load: float, headroom: float) -> int:
    """Observed load -> static capacity: headroom cushion, lane-aligned."""
    return max(8, -(-int(math.ceil(load * headroom)) // 8) * 8)


def plan_dist_join_sizes(left_keys: Sequence[np.ndarray],
                         right_keys: Sequence[np.ndarray], *, world: int,
                         how: str = "inner", headroom: float = 1.25,
                         local_impl: str | None = None,
                         num_buckets: int | None = None) -> dict:
    """Host-side whole-join capacity oracle for a shuffle-strategy
    :func:`dist_join`.

    Sizes every static capacity of the distributed join from the *actual*
    key distributions, once, before any device work: the shuffle slabs
    (per-destination slot bound and receive capacity per side), the join
    output capacity, and — under the hash local backend — the per-bucket
    build/probe slab depths.  Equal keys co-locate (partition id and
    bucket id are functions of the key value only), so per-destination and
    per-bucket loads are exact host-side regardless of how rows are
    block-distributed among senders: a destination receives at most the
    total count of its keys, whatever the sender split.  Every bound is
    the observed per-key/per-destination maximum times ``headroom``,
    rounded up to a multiple of 8 — the distributed join's overflow
    counter is zero by construction for these keys, with static shapes
    far below the blind ``overcommit`` heuristics.

    ``left_keys`` / ``right_keys`` are parallel sequences of *concrete*
    key columns (the same arrays later fed to :func:`distribute_table`);
    the per-key hash chain reuses the engine's own ``hash_columns`` /
    ``bucketing.bucket_ids``, so the plan prices exactly the routing the
    shuffle and the hash kernels will perform.

    Returns ``{"shuffle_sizes": {"left": (slots_per_dest, out_capacity),
    "right": ...}, "out_capacity": ..., "local_join_sizes": ...}`` —
    keyword-compatible with :func:`dist_join` (``local_join_sizes`` is
    ``None`` unless ``local_impl='hash'``).
    """
    lcols = [np.asarray(_narrow_column(f"k{i}", np.asarray(c)))
             for i, c in enumerate(left_keys)]
    rcols = [np.asarray(_narrow_column(f"k{i}", np.asarray(c)))
             for i, c in enumerate(right_keys)]
    nl, nr = len(lcols[0]), len(rcols[0])
    # partition ids with each side's own dtype (what shuffle hashes) ...
    pid = np.concatenate([
        np.asarray(hash_columns([jnp.asarray(c) for c in lcols])
                   % jnp.uint32(world)).astype(np.int64),
        np.asarray(hash_columns([jnp.asarray(c) for c in rcols])
                   % jnp.uint32(world)).astype(np.int64)])
    # ... but key identity in the promoted common dtype (what the local
    # join compares), mirroring the engine's key promotion rule.
    planes = []
    for lc, rc in zip(lcols, rcols):
        dt = np.promote_types(lc.dtype, rc.dtype)
        dt = np.float32 if np.issubdtype(dt, np.floating) else np.int32
        planes.append(np.asarray(_bucketing.key_bits(
            jnp.asarray(np.concatenate([lc.astype(dt), rc.astype(dt)])))))
    bits = np.stack(planes, axis=1)                       # (nl+nr, K)
    uniq, first, inv = np.unique(bits, axis=0, return_index=True,
                                 return_inverse=True)
    inv = inv.reshape(-1)
    n_uniq = uniq.shape[0]
    cl = np.bincount(inv[:nl], minlength=n_uniq).astype(np.float64)
    cr = np.bincount(inv[nl:], minlength=n_uniq).astype(np.float64)
    upid = pid[first]

    def _side(counts):
        recv = np.bincount(upid, weights=counts, minlength=world)
        cap = _pad8(recv.max() if n_uniq else 0, headroom)
        return cap, cap        # slots_per_dest bound == receive capacity

    lsizes, rsizes = _side(cl), _side(cr)
    matches = cl * cr
    if how == "left":
        matches = matches + np.where(cr == 0, cl, 0)
    per_dest = np.bincount(upid, weights=matches, minlength=world)
    out_cap = _pad8(per_dest.max() if n_uniq else 0, headroom)

    local_sizes = None
    if local_impl == "hash":
        B = num_buckets or _bucketing.default_bucket_count(
            max(lsizes[1], rsizes[1]))
        ubid = np.asarray(_bucketing.bucket_ids(
            tuple(jnp.asarray(uniq[:, k]) for k in range(uniq.shape[1])),
            B)).astype(np.int64)
        db = upid * B + ubid
        local_sizes = dict(
            num_buckets=B,
            bucket_capacity=_pad8(
                np.bincount(db, weights=cr, minlength=world * B).max()
                if n_uniq else 0, headroom),
            probe_capacity=_pad8(
                np.bincount(db, weights=cl, minlength=world * B).max()
                if n_uniq else 0, headroom))
    return {"shuffle_sizes": {"left": lsizes, "right": rsizes},
            "out_capacity": out_cap, "local_join_sizes": local_sizes}


def shuffle(ctx: HptmtContext, table: Table, key_cols: Sequence[str],
            *, overcommit: float = 2.0,
            slots_per_dest: int | None = None,
            out_capacity: int | None = None):
    """Hash shuffle: co-locate equal keys on the same shard."""
    s, oc = default_shuffle_sizes(ctx, table.capacity, overcommit)
    pid = partition_ids(table, list(key_cols), ctx.world_size)
    return shuffle_by_pid(ctx, table, pid,
                          slots_per_dest or s, out_capacity or oc)


# --------------------------------------------------------------------------
# Distributed relational operators = shuffle + local op (paper Table 5)
# --------------------------------------------------------------------------


def dist_join(ctx: HptmtContext, left: Table, right: Table, *,
              left_on: Sequence[str], right_on: Sequence[str] | None = None,
              how: str = "inner", out_capacity: int | None = None,
              overcommit: float = 2.0, strategy: str = "shuffle",
              local_impl: str | None = None,
              local_join_sizes: Mapping[str, int] | None = None,
              shuffle_sizes: Mapping[str, tuple[int, int]] | None = None):
    """Distributed join (paper Fig. 4 operator).

    ``strategy='shuffle'``: hash-shuffle both sides on the key, local join
    (Cylon's algorithm).  ``strategy='broadcast'``: all_gather the (small)
    right side and join locally — no shuffle of the big side (beyond-paper
    optimization; pick when |right| << |left|).

    ``local_impl`` selects the local join backend ('sortmerge' | 'hash',
    default ``kernel_backend.join_impl()``); ``local_join_sizes`` forwards
    hash-backend static sizing (``num_buckets`` / ``bucket_capacity`` /
    ``probe_capacity``) — both backends return drop-in identical results,
    so the whole distributed join runs hash-local under one shard_map.
    ``shuffle_sizes`` overrides the blind ``overcommit`` shuffle heuristic
    with explicit per-side ``{"left"/"right": (slots_per_dest,
    out_capacity)}`` bounds — :func:`plan_dist_join_sizes` computes these
    (and ``out_capacity`` / ``local_join_sizes``) exactly from concrete
    keys host-side.
    """
    right_on = list(right_on) if right_on is not None else list(left_on)
    jkw = dict(local_join_sizes or {})
    if strategy == "broadcast":
        g = all_gather_table(ctx, right)
        out, jdrop = L.join(left, g, left_on=list(left_on),
                            right_on=right_on, how=how,
                            out_capacity=out_capacity or left.capacity,
                            impl=local_impl, return_overflow=True, **jkw)
        return out, jax.lax.psum(jdrop, ctx.row_axes)
    # hash both sides with the same key columns -> same pid function
    lp = partition_ids(left, list(left_on), ctx.world_size)
    rp_tbl = right.rename(dict(zip(right_on, left_on))) \
        if right_on != list(left_on) else right
    rp = partition_ids(rp_tbl, list(left_on), ctx.world_size)
    if shuffle_sizes is not None:
        ls, loc = shuffle_sizes["left"]
        rs, roc = shuffle_sizes["right"]
    else:
        ls, loc = default_shuffle_sizes(ctx, left.capacity, overcommit)
        rs, roc = default_shuffle_sizes(ctx, right.capacity, overcommit)
    lsh, ldrop = shuffle_by_pid(ctx, left, lp, ls, loc)
    rsh, rdrop = shuffle_by_pid(ctx, right, rp, rs, roc)
    # the local join's overflow (output capacity, hash bucket/probe slabs)
    # joins the shuffle drops in one "rows lost anywhere" counter
    out, jdrop = L.join(lsh, rsh, left_on=list(left_on), right_on=right_on,
                        how=how, out_capacity=out_capacity or loc,
                        impl=local_impl, return_overflow=True, **jkw)
    return out, ldrop + rdrop + jax.lax.psum(jdrop, ctx.row_axes)


def dist_groupby(ctx: HptmtContext, table: Table, by: Sequence[str],
                 aggs: Mapping[str, Sequence[str] | str],
                 overcommit: float = 2.0, local_impl: str | None = None,
                 groupby_sizes: Mapping[str, int] | None = None):
    """Distributed GroupBy+Aggregate: shuffle on keys + local groupby.

    ``local_impl`` selects the local aggregation backend ('sort' | 'hash',
    default ``kernel_backend.groupby_impl()``); ``groupby_sizes`` forwards
    hash-backend static sizing (``num_buckets`` / ``bucket_capacity``).
    Both backends return drop-in identical results, so the whole
    distributed groupby runs hash-local under one shard_map; the hash
    path's bucket-overflow drops join the shuffle drops in the returned
    counter.

    Note: mean aggregations are computed from shuffled raw rows, so they are
    exact (not an average-of-averages)."""
    sh, dropped = shuffle(ctx, table, by, overcommit=overcommit)
    out, gdrop = L.groupby_aggregate(sh, list(by), aggs, impl=local_impl,
                                     return_overflow=True,
                                     **dict(groupby_sizes or {}))
    return out, dropped + jax.lax.psum(gdrop, ctx.row_axes)


def dist_unique(ctx: HptmtContext, table: Table, subset: Sequence[str],
                overcommit: float = 2.0, local_impl: str | None = None,
                groupby_sizes: Mapping[str, int] | None = None):
    """Paper §4.3: 'the distributed unique operator ensures no duplicate
    records are used for deep learning across all processes'.

    Shuffle on the key + local drop_duplicates — which under
    ``local_impl='hash'`` is a *key-only hash groupby* on the
    ``kernels/hash_groupby`` plan, sharing the pluggable aggregation
    backend (``groupby_sizes`` forwards its static sizing)."""
    sh, dropped = shuffle(ctx, table, subset, overcommit=overcommit)
    out, gdrop = L.drop_duplicates(sh, list(subset), impl=local_impl,
                                   return_overflow=True,
                                   **dict(groupby_sizes or {}))
    return out, dropped + jax.lax.psum(gdrop, ctx.row_axes)


def dist_difference(ctx: HptmtContext, a: Table, b: Table,
                    on: Sequence[str], overcommit: float = 2.0,
                    local_impl: str | None = None,
                    semi_sizes: Mapping[str, int] | None = None):
    """Distributed Difference: shuffle both sides on the key + local
    difference.  Equal keys co-locate (the partition hash is over key
    *values*), so per-shard membership is global membership.

    ``local_impl`` selects the local semi-join backend ('sortmerge' |
    'hash', default ``kernel_backend.semi_impl()``); ``semi_sizes``
    forwards hash-backend static sizing (``num_buckets`` /
    ``bucket_capacity`` / ``probe_capacity``).  The hash path's slab
    overflow drops join the shuffle drops in the returned counter."""
    ash, d1 = shuffle(ctx, a, on, overcommit=overcommit)
    bsh, d2 = shuffle(ctx, b, on, overcommit=overcommit)
    out, over = L.difference(ash, bsh, on=list(on), impl=local_impl,
                             return_overflow=True,
                             **dict(semi_sizes or {}))
    return out, d1 + d2 + jax.lax.psum(over, ctx.row_axes)


def dist_intersect(ctx: HptmtContext, a: Table, b: Table,
                   on: Sequence[str], overcommit: float = 2.0,
                   local_impl: str | None = None,
                   dedup_impl: str | None = None,
                   semi_sizes: Mapping[str, int] | None = None):
    """Distributed Intersect: shuffle both sides on the key + local
    intersect.  ``local_impl`` selects the local semi-join backend
    ('sortmerge' | 'hash'), ``dedup_impl`` the local dedup backend
    ('sort' | 'hash'); ``semi_sizes`` forwards hash-backend static
    sizing.  Slab-overflow drops join the shuffle drops in the counter."""
    ash, d1 = shuffle(ctx, a, on, overcommit=overcommit)
    bsh, d2 = shuffle(ctx, b, on, overcommit=overcommit)
    out, over = L.intersect(ash, bsh, on=list(on), impl=local_impl,
                            dedup_impl=dedup_impl, return_overflow=True,
                            **dict(semi_sizes or {}))
    return out, d1 + d2 + jax.lax.psum(over, ctx.row_axes)


def dist_isin(ctx: HptmtContext, table: Table, col: str, values: Table,
              values_col: str, overcommit: float = 2.0,
              local_impl: str | None = None,
              semi_sizes: Mapping[str, int] | None = None):
    """Distributed membership filter: rows of ``table`` whose ``col`` is
    present among ``values[values_col]`` anywhere in the world.

    Both sides are shuffled on their key column — ``partition_ids``
    hashes column *values* (name-independent), so a table row and its
    matching value land on the same shard — then the local :func:`isin`
    mask selects.  ``local_impl`` / ``semi_sizes`` as in
    :func:`dist_difference`.  Returns ``(filtered_table, dropped)``."""
    tsh, d1 = shuffle(ctx, table, [col], overcommit=overcommit)
    vsh, d2 = shuffle(ctx, values, [values_col], overcommit=overcommit)
    mask, over = L.isin(tsh, col, vsh, values_col, impl=local_impl,
                        return_overflow=True, **dict(semi_sizes or {}))
    return L.select(tsh, mask), d1 + d2 + jax.lax.psum(over, ctx.row_axes)


# --------------------------------------------------------------------------
# Distributed sort (sample sort) — paper Table 5 "Sorting tables"
# --------------------------------------------------------------------------


def dist_sort(ctx: HptmtContext, table: Table, by: Sequence[str],
              ascending: bool = True, n_samples: int = 32,
              overcommit: float = 2.0, local_impl: str | None = None):
    """Sample-sort: local sort, splitter all_gather, range partition,
    all_to_all, local sort.  Globally sorted = shard order + local order.

    ``local_impl`` selects the local sort backend ('xla' | 'radix',
    default ``kernel_backend.sort_impl()``) for the pre-shuffle and final
    local sorts; under 'radix' the gathered splitter candidates are also
    ranked by the radix engine, so the whole distributed sort is
    sort-primitive-free.  Both backends return drop-in bit-identical
    results (same splitters, same routing, same shard-local order)."""
    by = list(by)
    impl = local_impl or _default_sort_impl()
    world = ctx.world_size
    ts = L.sort_values(table, by, ascending=ascending, impl=impl)
    cap = ts.capacity
    s = min(n_samples, cap)
    # evenly sample valid rows (clamp handles nvalid < s)
    pos = (jnp.arange(s) * jnp.maximum(ts.nvalid, 1)) // s
    pos = jnp.clip(pos, 0, cap - 1)
    valid_s = jnp.arange(s) < jnp.minimum(ts.nvalid, s)
    sample_keys = []
    for k in by:
        col = L._sort_key(ts.columns[k], ascending)[pos]
        col = jnp.where(valid_s, col, L._sentinel_max(col))
        sample_keys.append(col)
    gathered = [jax.lax.all_gather(c, ctx.row_axes, tiled=True)
                for c in sample_keys]                     # (world*s,)
    if impl == "radix":
        sperm = radix_permutation(tuple(gathered),
                                  jnp.zeros((world * s,), bool),
                                  impl=radix_impl())
        sorted_keys = tuple(c[sperm] for c in gathered)
    else:
        iota = jnp.arange(world * s, dtype=jnp.int32)
        sorted_keys = jax.lax.sort((*gathered, iota),
                                   num_keys=len(gathered),
                                   is_stable=True)[:-1]
    # world-1 splitters at quantile positions
    spl_pos = (jnp.arange(1, world) * (world * s)) // world
    splitters = tuple(op[spl_pos] for op in sorted_keys)
    row_keys = tuple(
        jnp.where(ts.valid_mask,
                  L._sort_key(ts.columns[k], ascending),
                  L._sentinel_max(ts.columns[k]))
        for k in by)
    pid = _rank_against_splitters(splitters, row_keys)
    slots, out_cap = default_shuffle_sizes(ctx, cap, overcommit)
    sh, dropped = shuffle_by_pid(ctx, ts, pid, slots, out_cap)
    return L.sort_values(sh, by, ascending=ascending, impl=impl), dropped


def _rank_against_splitters(splitters: tuple, row_keys: tuple) -> jnp.ndarray:
    """pid = number of splitters <= key (vectorized lex compare)."""
    nspl = splitters[0].shape[0]
    cap = row_keys[0].shape[0]
    pid = jnp.zeros((cap,), jnp.int32)
    for i in range(nspl):
        spl = tuple(s[i] for s in splitters)
        spl_b = tuple(jnp.broadcast_to(s, (cap,)) for s in spl)
        le = ~L._tuple_less(row_keys, spl_b)   # splitter <= key
        pid = pid + le.astype(jnp.int32)
    return pid


# --------------------------------------------------------------------------
# Repartition / rebalance — skew (straggler) mitigation
# --------------------------------------------------------------------------


def dist_repartition(ctx: HptmtContext, table: Table,
                     overcommit: float = 1.5):
    """Exact load rebalance: row global-rank r goes to shard r // ceil(N/W).

    BSP stragglers are dominated by data skew after shuffles (DESIGN.md §4);
    this restores near-perfect balance with one all_to_all."""
    world = ctx.world_size
    nv = table.nvalid
    counts = jax.lax.all_gather(nv, ctx.row_axes)          # (world,)
    my = ctx.axis_index()
    prefix = jnp.sum(jnp.where(jnp.arange(world) < my, counts, 0))
    total = jnp.sum(counts)
    target = jnp.maximum((total + world - 1) // world, 1)
    r = prefix + jnp.arange(table.capacity, dtype=jnp.int32)
    pid = jnp.minimum(r // target, world - 1).astype(jnp.int32)
    # one sender contributes at most min(capacity, target) rows to a single
    # destination, and each destination receives at most target <= capacity
    # rows in total -> capacity bounds are exact (never drops).
    return shuffle_by_pid(ctx, table, pid,
                          slots_per_dest=table.capacity,
                          out_capacity=table.capacity)


# --------------------------------------------------------------------------
# Distributed column scaling (sklearn StandardScaler with *global* stats)
# --------------------------------------------------------------------------


def dist_standard_scale(ctx: HptmtContext, table: Table,
                        cols: Sequence[str],
                        local_impl: str | None = None) -> Table:
    """(x - mean) / std per column with mean/std over ALL shards' valid
    rows (exact psum moments) — the distributed equivalent of the paper's
    sklearn preprocessing step.  Per-shard scaling would silently change
    results with parallelism; this keeps them parallelism-invariant.

    Two-pass like the local op: global means first (psum of sums), then
    the psum'd variance of deviations about them — exact even when
    ``|mean| >> std`` (the one-pass ``E[x^2] - m^2`` form cancels in
    float32).  ``local_impl`` selects how each shard computes its
    per-column moments (``L.column_moments``): inline masked reductions
    (None, the fast path) or the pluggable 'sort'/'hash' aggregation
    backend — so a whole preprocessing pipeline can run one backend end
    to end."""
    out = dict(table.columns)
    s1, _, n = L.column_moments(table, cols, impl=local_impl)
    n = jnp.maximum(jax.lax.psum(n, ctx.row_axes), 1.0)
    means = {k: jax.lax.psum(s1[k], ctx.row_axes) / n for k in cols}
    _, sd2, _ = L.column_moments(table, cols, impl=local_impl,
                                 center=means)
    for k in cols:
        x = out[k].astype(jnp.float32)
        v = jax.lax.psum(sd2[k], ctx.row_axes) / n
        out[k] = (x - means[k]) / jnp.sqrt(v + 1e-12)
    return Table(columns=out, nvalid=table.nvalid)


# --------------------------------------------------------------------------
# Broadcast / gather of tables (paper Table 4: Broadcast for tables)
# --------------------------------------------------------------------------


def all_gather_table(ctx: HptmtContext, table: Table) -> Table:
    """Replicate a (small) table on every shard: capacity*world rows."""
    world = ctx.world_size
    cap = table.capacity
    valid = table.valid_mask
    cols = {}
    for k, v in table.columns.items():
        g = jax.lax.all_gather(v, ctx.row_axes, tiled=True)
        cols[k] = g
    gvalid = jax.lax.all_gather(valid, ctx.row_axes, tiled=True)
    perm = stable_partition_perm(gvalid, impl=radix_impl())
    out = Table(columns={k: v[perm] for k, v in cols.items()},
                nvalid=jnp.sum(gvalid, dtype=jnp.int32))
    return out


# --------------------------------------------------------------------------
# Whole-pipeline runner: one shard_map = one BSP program
# --------------------------------------------------------------------------


@dataclasses.dataclass
class DistributedPipeline:
    """Wrap a table pipeline ``fn(ctx, *local_tables, **kw) -> pytree`` into
    a single jitted shard_map program (the paper's single-source,
    single-runtime execution: data engineering composed as one SPMD
    program).

    Output pytree leaves: ``Table`` -> row-sharded global table; scalar
    leaves (e.g. the ``dropped`` counters) are auto-lifted to a leading
    per-shard axis of size 1 and come back stacked ``(world,)``; other
    arrays must already carry a leading per-shard axis.

    The jitted program is built once per instance and reused across calls
    (kwarg-free calls only — kwargs close over the trace, so a call with
    kwargs rebuilds).  Chunk loops (``core/morsel.py``) rely on this:
    every morsel re-enters the *same* compiled executable, so the
    per-chunk cost is execution, not tracing.

    ``donate_argnums`` donates the corresponding *table* arguments'
    buffers to the call (``jax.jit`` donation): chunk loops donate the
    fold accumulator they rebind each iteration — append/merge keeps its
    static capacity, so XLA writes the fold in place instead of
    allocating a fresh accumulator per chunk.  Never donate a table the
    caller reads again (e.g. the resident build side of a probe loop),
    and don't donate tables whose buffers match no output shape (e.g.
    per-morsel chunks vs. overcommitted shuffle slabs) — that donation
    is a warning-generating no-op.
    """

    ctx: HptmtContext
    fn: Callable
    donate_argnums: tuple[int, ...] = ()
    _jitted: Callable | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    def _build(self, **kwargs):
        ctx = self.ctx
        spec = ctx.rows_spec

        def lift(x):
            if isinstance(x, Table):
                return _to_global(x)
            x = jnp.asarray(x)
            return x[None] if x.ndim == 0 else x

        def wrapped(*ts):
            local = [_to_local(t) for t in ts]
            out = self.fn(ctx, *local, **kwargs)
            return jax.tree_util.tree_map(
                lift, out, is_leaf=lambda x: isinstance(x, Table))

        # `spec` is a valid pytree *prefix* for the whole in/out trees
        f = shard_map(wrapped, mesh=ctx.mesh, in_specs=spec,
                      out_specs=spec)
        return jax.jit(f, donate_argnums=tuple(self.donate_argnums))

    def __call__(self, *tables: Table, **kwargs):
        if kwargs:
            return self._build(**kwargs)(*tables)
        if self._jitted is None:
            self._jitted = self._build()
        return self._jitted(*tables)
