"""Select kernel implementations per backend.

Pallas TPU kernels are the *target*; on CPU (this container) the pure-jnp
references execute instead, and tests exercise the kernels via
``interpret=True``.  Environment overrides:

* ``REPRO_KERNEL_IMPL``  — table kernels (radix partition, hash-join
  probe, hash-groupby accumulate): ``ref | pallas | pallas_interpret``;
* ``REPRO_JOIN_IMPL``    — local join algorithm: ``sortmerge | hash``;
* ``REPRO_GROUPBY_IMPL`` — local groupby/dedup algorithm: ``sort | hash``;
* ``REPRO_SORT_IMPL``    — local sort/OrderBy algorithm: ``xla | radix``;
* ``REPRO_SEMI_IMPL``    — local semi-join/membership algorithm
  (isin / intersect / difference): ``sortmerge | hash``;
* ``REPRO_ATTN_IMPL`` / ``REPRO_MAMBA_IMPL`` — model kernels.
"""
import os

import jax


def backend_platform() -> str:
    return jax.devices()[0].platform


def table_kernel_impl() -> str:
    """Impl for the table-engine Pallas kernels (radix + hash-join probe)."""
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    return "pallas" if backend_platform() == "tpu" else "ref"


# historical name — the radix kernel was the first table kernel
radix_impl = table_kernel_impl


def join_impl() -> str:
    """Local join algorithm: 'sortmerge' (default) or 'hash'."""
    env = os.environ.get("REPRO_JOIN_IMPL")
    if env:
        return env
    return "sortmerge"


def groupby_impl() -> str:
    """Local groupby/aggregate/dedup algorithm: 'sort' (default) or
    'hash'."""
    env = os.environ.get("REPRO_GROUPBY_IMPL")
    if env:
        return env
    return "sort"


def sort_impl() -> str:
    """Local sort/OrderBy algorithm: 'xla' (``jax.lax.sort``, default) or
    'radix' (multi-pass LSD radix rank on ``kernels/radix_sort`` — no
    ``sort`` primitive anywhere on the path)."""
    env = os.environ.get("REPRO_SORT_IMPL")
    if env:
        return env
    return "xla"


def semi_impl() -> str:
    """Local semi-join/membership algorithm (isin / _semi_mask /
    intersect / difference): 'sortmerge' (binary search over sorted keys,
    default) or 'hash' (bucketed build+probe membership on
    ``kernels/hash_semi`` — no join materialization, no ``sort``
    primitive anywhere on the path)."""
    env = os.environ.get("REPRO_SEMI_IMPL")
    if env:
        return env
    return "sortmerge"


def attention_impl() -> str:
    env = os.environ.get("REPRO_ATTN_IMPL")
    if env:
        return env
    return "pallas" if backend_platform() == "tpu" else "xla"


def mamba_impl() -> str:
    env = os.environ.get("REPRO_MAMBA_IMPL")
    if env:
        return env
    return "pallas" if backend_platform() == "tpu" else "xla"
