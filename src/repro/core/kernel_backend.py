"""Select kernel implementations per backend.

Pallas TPU kernels are the *target*; on CPU (this container) the pure-jnp
references execute instead, and tests exercise the kernels via
``interpret=True``.  ``REPRO_KERNEL_IMPL`` overrides (ref | pallas |
pallas_interpret).
"""
import os

import jax


def backend_platform() -> str:
    return jax.devices()[0].platform


def radix_impl() -> str:
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    return "pallas" if backend_platform() == "tpu" else "ref"


def attention_impl() -> str:
    env = os.environ.get("REPRO_ATTN_IMPL")
    if env:
        return env
    return "pallas" if backend_platform() == "tpu" else "xla"


def mamba_impl() -> str:
    env = os.environ.get("REPRO_MAMBA_IMPL")
    if env:
        return env
    return "pallas" if backend_platform() == "tpu" else "xla"
