"""Per-backend tuning table for the radix / bucketing kernel family.

Every kernel in this package is shaped by two static knobs:

* ``radix_bits`` — digits per LSD counting-sort pass (``kernels/radix_sort``):
  more bits means fewer passes but a wider ``2**radix_bits`` one-hot per
  pass;
* ``tile`` — rows per Pallas grid step (``hash_partition``,
  ``fused_bucketing``, ``radix_sort``): wider tiles amortize grid overhead
  but grow the per-step ``tile x P`` one-hot's VMEM footprint.

The right trade-off depends on the backend (interpreted CPU vs real TPU
VPU) and the problem size, so callers resolve the knobs through
:func:`tuned` instead of hard-coding them.  Resolution order:

1. ``REPRO_RADIX_BITS`` / ``REPRO_TILE`` env overrides (highest priority —
   the escape hatch for a known-good setting);
2. the process-local cache, keyed by ``(knob, backend, dtype,
   capacity_bucket)`` where ``capacity_bucket`` is the capacity rounded up
   to a power of two (so one sweep covers a whole size class);
3. with ``REPRO_AUTOTUNE=1``, a first-use measurement sweep over the
   candidate values (timed on a synthetic workload of the bucketed
   capacity, result cached);
4. otherwise the static per-backend default.

The sweep is deliberately cheap (one warmup + one timed run per
candidate, capacity capped) — it pays for itself on any workload that
reuses a size class, and the cache means it runs once per process.
"""
import functools
import os
import time

# per-backend defaults: the interpreted/ref paths on CPU favor fewer
# one-hot columns per pass; the compiled Pallas path defaults match the
# TPU-aligned shapes the kernels were written for (tile and one-hot width
# as multiples of the 128-lane VPU registers).
_DEFAULTS = {
    "radix_bits": {"ref": 8, "pallas": 8, "pallas_interpret": 8},
    "tile": {"ref": 1024, "pallas": 1024, "pallas_interpret": 1024},
}
# candidate grids for the measurement sweep.  radix_bits candidates keep
# the per-pass one-hot narrow enough to materialize on any backend
# (2**11 = 2048 columns at most); tile candidates stay VMEM-safe at the
# widest one-hot the bucketed kernels build (tile * 513 * 4 B).
_CANDIDATES = {
    "radix_bits": (4, 8, 11),
    "tile": (512, 1024, 2048),
}
_ENV = {"radix_bits": "REPRO_RADIX_BITS", "tile": "REPRO_TILE"}
_SWEEP_CAP = 1 << 16   # rows of synthetic data per timed candidate

_cache: dict = {}


def clear_cache() -> None:
    """Drop all cached tuning decisions (tests / fresh sweeps)."""
    _cache.clear()


def _env_int(name: str):
    v = os.environ.get(name, "").strip()
    return int(v) if v else None


def _capacity_bucket(capacity: int) -> int:
    return 1 << max(0, int(capacity - 1).bit_length()) if capacity > 1 else 1


def _time_once(fn) -> float:
    fn()                                   # warmup (trace + compile)
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _sweep(knob: str, backend: str, capacity: int) -> int:
    """Measure each candidate on a synthetic workload, return the fastest."""
    import jax
    import jax.numpy as jnp

    from .radix_sort.ops import _radix_permutation

    n = max(8, min(capacity, _SWEEP_CAP))
    # deterministic pseudo-random keys (a Weyl sequence): enough entropy
    # to exercise every digit pass without jax.random's setup cost
    col = (jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761)) \
        .astype(jnp.int32)
    invalid = jnp.zeros((n,), bool)
    best, best_t = None, None
    for cand in _CANDIDATES[knob]:
        kw = {"radix_bits": cand} if knob == "radix_bits" else {"tile": cand}

        def run(kw=kw):
            jax.block_until_ready(_radix_permutation(
                (col,), invalid, impl=backend, **{
                    "radix_bits": _DEFAULTS["radix_bits"][backend],
                    "tile": _DEFAULTS["tile"][backend], **kw}))

        t = _time_once(run)
        if best_t is None or t < best_t:
            best, best_t = cand, t
    return best


def tuned(knob: str, backend: str, capacity: int,
          dtype: str = "int32") -> int:
    """Resolve ``knob`` ('radix_bits' | 'tile') for one kernel call.

    ``backend`` is the kernel impl string ('ref' | 'pallas' |
    'pallas_interpret'); ``capacity`` the row capacity the kernel will
    run at (bucketed to a power of two for the cache key).
    """
    env = _env_int(_ENV[knob])
    if env is not None:
        return env
    key = (knob, backend, str(dtype), _capacity_bucket(capacity))
    if key not in _cache:
        if os.environ.get("REPRO_AUTOTUNE", "") == "1":
            _cache[key] = _sweep(knob, backend, key[3])
        else:
            _cache[key] = _DEFAULTS[knob].get(backend,
                                              _DEFAULTS[knob]["ref"])
    return _cache[key]


def radix_params(backend: str, capacity: int, radix_bits=None, tile=None):
    """(radix_bits, tile) with ``None`` entries resolved via :func:`tuned`
    — the shared resolver for the radix/bucketing op wrappers."""
    if radix_bits is None:
        radix_bits = tuned("radix_bits", backend, capacity)
    if tile is None:
        tile = tuned("tile", backend, capacity)
    return radix_bits, tile
