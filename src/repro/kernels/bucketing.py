"""Shared bucketed-slab machinery for the hash table kernels.

``hash_join`` and ``hash_groupby`` both start the same way: rows are
scattered into per-bucket *slabs* (static ``num_buckets x slab_cap``
layouts) keyed by a murmur-mixed hash of the key bit-planes, with stable
within-bucket order equal to original row order.  That grouping — key
bit-plane extraction, bucket-id hashing, stable within-bucket ranks, and
the slot scatter with overflow counting — lives here so every bucketed
kernel package shares one implementation.

Semantics contract (relied on by the kernels' bit-identicality promise):

* equal keys always land in the same bucket (the hash sees only the key
  bit-planes, with ``-0.0`` floats normalized to ``+0.0``);
* slot order within a bucket is original row order (stable ranks), so
  per-bucket scans see rows in table order;
* a bucket holds at most ``slab_cap`` rows — overflowing rows are dropped
  and *counted*, never silently lost (callers size capacities so the
  counter stays zero).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np

from .hash_partition import radix_histogram_ranks
from .radix_sort import grouped_ranks

# the single-pass radix ref/kernel materializes an (n, P) one-hot; past
# ~512 buckets switch to the multi-pass rank (kernels/radix_sort), whose
# per-pass one-hot stays at 2^radix_bits — every bucket count is
# sort-free.  The cap still bounds the cheaper single-pass path and the
# per-bucket slab grids the kernels iterate over.
MAX_RADIX_BUCKETS = 512

# up to this table capacity, default slab sizing uses full-capacity slabs:
# every key distribution (including all-equal keys) fits with zero
# overflow, and the per-bucket match matrix stays small enough for VMEM
# (512*512*4 B = 1 MiB << ~16 MiB/core).
EXACT_SLAB_CAP = 512


def key_bits(col: jnp.ndarray) -> jnp.ndarray:
    """Key column -> int32 bit-plane with exact equality semantics."""
    if jnp.issubdtype(col.dtype, jnp.floating):
        col = col.astype(jnp.float32)
        col = jnp.where(col == 0.0, jnp.zeros_like(col), col)  # -0.0 == 0.0
        return jax.lax.bitcast_convert_type(col, jnp.int32)
    return col.astype(jnp.int32)


def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 over uint32 (same family as core.partition)."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def bucket_ids(bits: tuple, num_buckets: int) -> jnp.ndarray:
    """Combined bucket id over key bit-planes (equal keys -> equal bucket)."""
    h = jnp.full(bits[0].shape, jnp.uint32(0x9E3779B9))
    for b in bits:
        u = jax.lax.bitcast_convert_type(b, jnp.uint32)
        h = _mix32(h ^ (u + jnp.uint32(0x9E3779B9) + (h << 6) + (h >> 2)))
    return (h % jnp.uint32(num_buckets)).astype(jnp.int32)


def bucket_ranks(bid: jnp.ndarray, num_buckets: int, impl: str):
    """(hist (P,), stable within-bucket ranks (n,)) for P = num_buckets.

    At most ``MAX_RADIX_BUCKETS`` buckets use the single-pass
    ``hash_partition`` one-hot; larger counts take the multi-pass radix
    rank (``kernels/radix_sort``) — sort-free either way, so the hash
    backends' no-``sort``-primitive guarantee holds at any bucket count.
    """
    if num_buckets <= MAX_RADIX_BUCKETS:
        return radix_histogram_ranks(bid, num_buckets, impl=impl)
    return grouped_ranks(bid, num_buckets, impl=impl)


def group_to_slabs(bits: tuple, valid: jnp.ndarray, num_buckets: int,
                   slab_cap: int, impl: str, payload: tuple = ()):
    """Scatter rows into (num_buckets * slab_cap) bucket-grouped slots.

    Returns ``(slab_bits (K, B*cap), occ (B*cap,), row (B*cap,),
    payload_slabs, dropped)`` where ``payload_slabs`` carries each extra
    ``payload`` column scattered with the same slot mapping (the
    hash-groupby value columns).  Slot order within a bucket is original
    row order (stable ranks).
    """
    cap = valid.shape[0]
    bid = jnp.where(valid, bucket_ids(bits, num_buckets), num_buckets)
    hist, ranks = bucket_ranks(bid, num_buckets + 1, impl)
    ok = valid & (ranks < slab_cap) & (bid < num_buckets)
    nslots = num_buckets * slab_cap
    slot = jnp.where(ok, bid * slab_cap + ranks, nslots)

    def scat(col):
        return jnp.zeros((nslots + 1,), col.dtype).at[slot].set(col)[:nslots]

    slab_bits = jnp.stack([scat(b) for b in bits])
    occ = scat(ok.astype(jnp.int32))
    row = scat(jnp.arange(cap, dtype=jnp.int32))
    payload_slabs = tuple(scat(p) for p in payload)
    dropped = jnp.sum(jnp.maximum(hist[:num_buckets] - slab_cap, 0),
                      dtype=jnp.int32)
    return slab_bits, occ, row, payload_slabs, dropped


def default_bucket_count(capacity: int) -> int:
    """~16-rows-per-bucket power-of-two bucket count, capped at
    ``MAX_RADIX_BUCKETS`` (the single-pass ranking's one-hot width)."""
    target = max(1, capacity // 16)
    return 1 << min(MAX_RADIX_BUCKETS.bit_length() - 1,
                    max(3, (target - 1).bit_length()))


def plan_bucket_sizes(key_cols, num_buckets: int | None = None, *,
                      headroom: float = 1.25, min_capacity: int = 8):
    """Two-pass (histogram, then size) bucket planner -> ``(num_buckets,
    slab_capacity)`` static sizes that are *distribution-proof* for the
    given keys.

    The one-pass auto-sizing heuristics assume ~uniform key spread above
    ``EXACT_SLAB_CAP``, so a heavily skewed key distribution can overflow
    its hottest bucket's slab.  This planner runs **host-side on concrete
    key columns** (valid rows only): pass 1 buckets the actual keys with
    the same ``bucket_ids`` hash the kernels use, pass 2 sizes the slab to
    the observed maximum bucket load (times ``headroom``, rounded up to a
    multiple of 8 for lane alignment) — the overflow counter is then zero
    by construction for these keys.  The default ``headroom`` keeps a
    small cushion above the observed max so a plan *reused* on slightly
    different keys (one more duplicate of the hottest key, the next chunk
    of the same stream) still fits; ``headroom=1.0`` sizes exactly to the
    observed keys.  Callers under ``jit``/``shard_map`` can't plan (the
    keys are traced); they keep the heuristic or pass explicit sizes.
    """
    cols = [np.asarray(c) for c in key_cols]
    n = int(cols[0].shape[0]) if cols else 0
    if num_buckets is None:
        num_buckets = default_bucket_count(n)
    if n == 0:
        return num_buckets, min_capacity
    bits = tuple(key_bits(jnp.asarray(c)) for c in cols)
    bid = np.asarray(bucket_ids(bits, num_buckets))
    load = int(np.bincount(bid, minlength=num_buckets).max())
    cap = int(math.ceil(load * headroom))
    return num_buckets, max(min_capacity, -(-cap // 8) * 8)
