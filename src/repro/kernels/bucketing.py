"""Shared bucketed-slab machinery for the hash table kernels.

``hash_join`` and ``hash_groupby`` both start the same way: rows are
scattered into per-bucket *slabs* (static ``num_buckets x slab_cap``
layouts) keyed by a murmur-mixed hash of the key bit-planes, with stable
within-bucket order equal to original row order.  That grouping — key
bit-plane extraction, bucket-id hashing, stable within-bucket ranks, and
the slot scatter with overflow counting — lives here so every bucketed
kernel package shares one implementation.

Semantics contract (relied on by the kernels' bit-identicality promise):

* equal keys always land in the same bucket (the hash sees only the key
  bit-planes, with ``-0.0`` floats normalized to ``+0.0``);
* slot order within a bucket is original row order (stable ranks), so
  per-bucket scans see rows in table order;
* a bucket holds at most ``slab_cap`` rows — overflowing rows are dropped
  and *counted*, never silently lost (callers size capacities so the
  counter stays zero).
"""
import jax
import jax.numpy as jnp

from .hash_partition import radix_histogram_ranks

# the radix ref/kernel materializes an (n, P) one-hot; past ~512 buckets
# fall back to a sort-based ranking (a TPU build would multi-pass
# instead).  Auto-sizing that promises a sort-free path must stay at or
# below this bucket count.
MAX_RADIX_BUCKETS = 512

# up to this table capacity, default slab sizing uses full-capacity slabs:
# every key distribution (including all-equal keys) fits with zero
# overflow, and the per-bucket match matrix stays small enough for VMEM
# (512*512*4 B = 1 MiB << ~16 MiB/core).
EXACT_SLAB_CAP = 512


def key_bits(col: jnp.ndarray) -> jnp.ndarray:
    """Key column -> int32 bit-plane with exact equality semantics."""
    if jnp.issubdtype(col.dtype, jnp.floating):
        col = col.astype(jnp.float32)
        col = jnp.where(col == 0.0, jnp.zeros_like(col), col)  # -0.0 == 0.0
        return jax.lax.bitcast_convert_type(col, jnp.int32)
    return col.astype(jnp.int32)


def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 over uint32 (same family as core.partition)."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def bucket_ids(bits: tuple, num_buckets: int) -> jnp.ndarray:
    """Combined bucket id over key bit-planes (equal keys -> equal bucket)."""
    h = jnp.full(bits[0].shape, jnp.uint32(0x9E3779B9))
    for b in bits:
        u = jax.lax.bitcast_convert_type(b, jnp.uint32)
        h = _mix32(h ^ (u + jnp.uint32(0x9E3779B9) + (h << 6) + (h >> 2)))
    return (h % jnp.uint32(num_buckets)).astype(jnp.int32)


def bucket_ranks(bid: jnp.ndarray, num_buckets: int, impl: str):
    """(hist (P,), stable within-bucket ranks (n,)) for P = num_buckets."""
    if num_buckets <= MAX_RADIX_BUCKETS:
        return radix_histogram_ranks(bid, num_buckets, impl=impl)
    hist = jnp.zeros((num_buckets,), jnp.int32).at[bid].add(1)
    order = jnp.argsort(bid, stable=True)
    sorted_bid = bid[order]
    n = bid.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    boundary = (iota == 0) | (sorted_bid != jnp.roll(sorted_bid, 1))
    start = jax.lax.associative_scan(jnp.maximum,
                                     jnp.where(boundary, iota, 0))
    ranks = jnp.zeros((n,), jnp.int32).at[order].set(iota - start)
    return hist, ranks


def group_to_slabs(bits: tuple, valid: jnp.ndarray, num_buckets: int,
                   slab_cap: int, impl: str, payload: tuple = ()):
    """Scatter rows into (num_buckets * slab_cap) bucket-grouped slots.

    Returns ``(slab_bits (K, B*cap), occ (B*cap,), row (B*cap,),
    payload_slabs, dropped)`` where ``payload_slabs`` carries each extra
    ``payload`` column scattered with the same slot mapping (the
    hash-groupby value columns).  Slot order within a bucket is original
    row order (stable ranks).
    """
    cap = valid.shape[0]
    bid = jnp.where(valid, bucket_ids(bits, num_buckets), num_buckets)
    hist, ranks = bucket_ranks(bid, num_buckets + 1, impl)
    ok = valid & (ranks < slab_cap) & (bid < num_buckets)
    nslots = num_buckets * slab_cap
    slot = jnp.where(ok, bid * slab_cap + ranks, nslots)

    def scat(col):
        return jnp.zeros((nslots + 1,), col.dtype).at[slot].set(col)[:nslots]

    slab_bits = jnp.stack([scat(b) for b in bits])
    occ = scat(ok.astype(jnp.int32))
    row = scat(jnp.arange(cap, dtype=jnp.int32))
    payload_slabs = tuple(scat(p) for p in payload)
    dropped = jnp.sum(jnp.maximum(hist[:num_buckets] - slab_cap, 0),
                      dtype=jnp.int32)
    return slab_bits, occ, row, payload_slabs, dropped
