"""Shared bucketed-slab machinery for the hash table kernels.

``hash_join``, ``hash_groupby`` and ``hash_semi`` all start the same way:
rows are scattered into per-bucket *slabs* (static ``num_buckets x
slab_cap`` layouts) keyed by a murmur-mixed hash of the key bit-planes,
with stable within-bucket order equal to original row order.  That
grouping — key bit-plane extraction, bucket-id hashing, stable
within-bucket ranks, and the slot scatter with overflow counting — lives
here so every bucketed kernel package shares one implementation.

The grouping is **single-pass**: the fused ``kernels/fused_bucketing``
kernel computes bucket ids, histogram and ranks in one sweep (hash and
one-hot fused per tile, nothing staged through HBM between them), and all
columns — key bit-planes, occupancy, row ids, payloads — are written to
their slabs by **one** stacked scatter (every column bitcast to an int32
plane first), not one scatter per column.  The conformance suites pin one
scatter per slab family in the jaxpr.

Semantics contract (relied on by the kernels' bit-identicality promise):

* equal keys always land in the same bucket (the hash sees only the key
  bit-planes, with ``-0.0`` floats normalized to ``+0.0``);
* slot order within a bucket is original row order (stable ranks), so
  per-bucket scans see rows in table order;
* a bucket holds at most ``slab_cap`` rows — overflowing rows are dropped
  and *counted*, never silently lost (callers size capacities so the
  counter stays zero).

:class:`BucketPlan` caches the per-side hashing state — bit-planes
extracted once, bucket ids memoized per bucket count — so the host-side
sizing pass (:func:`plan_bucket_sizes`) and the jitted kernel plans never
re-hash the same columns.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np

from .fused_bucketing import fused_bucket_ranks
from .fused_bucketing.ref import _mix32, bucket_ids  # noqa: F401  (canonical)
from .hash_partition import radix_histogram_ranks
from .radix_sort import grouped_ranks

# the single-pass fused ref/kernel materializes an (n, P+1) one-hot; past
# ~512 buckets switch to the multi-pass rank (kernels/radix_sort), whose
# per-pass one-hot stays at 2^radix_bits — every bucket count is
# sort-free.  The cap still bounds the cheaper single-pass path and the
# per-bucket slab grids the kernels iterate over.
MAX_RADIX_BUCKETS = 512

# up to this table capacity, default slab sizing uses full-capacity slabs:
# every key distribution (including all-equal keys) fits with zero
# overflow, and the per-bucket match matrix stays small enough for VMEM
# (512*512*4 B = 1 MiB << ~16 MiB/core).
EXACT_SLAB_CAP = 512


def key_bits(col: jnp.ndarray) -> jnp.ndarray:
    """Key column -> int32 bit-plane with exact equality semantics."""
    if jnp.issubdtype(col.dtype, jnp.floating):
        col = col.astype(jnp.float32)
        col = jnp.where(col == 0.0, jnp.zeros_like(col), col)  # -0.0 == 0.0
        return jax.lax.bitcast_convert_type(col, jnp.int32)
    return col.astype(jnp.int32)


def pack_i32(col: jnp.ndarray) -> jnp.ndarray:
    """Engine column -> int32 plane, value-preserving (floats bitcast, so
    the round-trip through :func:`unpack_i32` is exact — including NaNs
    and ``-0.0``).  The stacked single-scatter paths (slab grouping, the
    shuffle send/receive) move every column as one of these planes."""
    if col.dtype == jnp.int32:
        return col
    if col.dtype == jnp.float32:
        return jax.lax.bitcast_convert_type(col, jnp.int32)
    if col.dtype == jnp.bool_:
        return col.astype(jnp.int32)
    raise TypeError(f"unsupported engine column dtype {col.dtype} "
                    "(engine contract: int32 / float32 / bool)")


def unpack_i32(plane: jnp.ndarray, dtype) -> jnp.ndarray:
    """Inverse of :func:`pack_i32` for a plane of the given column dtype."""
    if dtype == jnp.int32:
        return plane
    if dtype == jnp.float32:
        return jax.lax.bitcast_convert_type(plane, jnp.float32)
    if dtype == jnp.bool_:
        return plane.astype(jnp.bool_)
    raise TypeError(f"unsupported engine column dtype {dtype} "
                    "(engine contract: int32 / float32 / bool)")


def bucket_ranks(bid: jnp.ndarray, num_buckets: int, impl: str):
    """(hist (P,), stable within-bucket ranks (n,)) for P = num_buckets.

    At most ``MAX_RADIX_BUCKETS`` buckets use the single-pass
    ``hash_partition`` one-hot; larger counts take the multi-pass radix
    rank (``kernels/radix_sort``) — sort-free either way, so the hash
    backends' no-``sort``-primitive guarantee holds at any bucket count.
    """
    if num_buckets <= MAX_RADIX_BUCKETS:
        return radix_histogram_ranks(bid, num_buckets, impl=impl)
    return grouped_ranks(bid, num_buckets, impl=impl)


def group_to_slabs(bits: tuple, valid: jnp.ndarray, num_buckets: int,
                   slab_cap: int, impl: str, payload: tuple = (),
                   bid: jnp.ndarray | None = None):
    """Scatter rows into (num_buckets * slab_cap) bucket-grouped slots.

    Returns ``(slab_bits (K, B*cap), occ (B*cap,), row (B*cap,),
    payload_slabs, dropped)`` where ``payload_slabs`` carries each extra
    ``payload`` column scattered with the same slot mapping (the
    hash-groupby value columns).  Slot order within a bucket is original
    row order (stable ranks).

    With ``bid=None`` the bucket ids come out of the fused single-pass
    kernel (hash + histogram + ranks in one sweep); a caller holding
    *precomputed* ids (``BucketPlan.bucket_ids_for`` — the eager sizing
    path already hashed the keys host-side) passes them in and only the
    histogram/rank pass runs.  Either way all columns land in their slabs
    via one stacked scatter.
    """
    cap = valid.shape[0]
    if bid is not None:
        bid = jnp.where(valid, bid, num_buckets)
        hist, ranks = bucket_ranks(bid, num_buckets + 1, impl)
    elif num_buckets <= MAX_RADIX_BUCKETS:
        bid, hist, ranks = fused_bucket_ranks(bits, valid, num_buckets,
                                              impl=impl)
    else:
        bid = jnp.where(valid, bucket_ids(bits, num_buckets), num_buckets)
        hist, ranks = grouped_ranks(bid, num_buckets + 1, impl=impl)
    ok = valid & (ranks < slab_cap) & (bid < num_buckets)
    nslots = num_buckets * slab_cap
    slot = jnp.where(ok, bid * slab_cap + ranks, nslots)

    # one scatter for every column: key planes, occupancy, row ids and
    # payloads stack into (ncols, n) int32 and land in (ncols, nslots)
    # together (slot nslots is the shared trash column).
    num_keys = len(bits)
    planes = (list(bits)
              + [ok.astype(jnp.int32), jnp.arange(cap, dtype=jnp.int32)]
              + [pack_i32(p) for p in payload])
    stacked = jnp.stack(planes)
    buf = (jnp.zeros((len(planes), nslots + 1), jnp.int32)
           .at[:, slot].set(stacked)[:, :nslots])
    slab_bits = buf[:num_keys]
    occ = buf[num_keys]
    row = buf[num_keys + 1]
    payload_slabs = tuple(unpack_i32(buf[num_keys + 2 + i], p.dtype)
                          for i, p in enumerate(payload))
    dropped = jnp.sum(jnp.maximum(hist[:num_buckets] - slab_cap, 0),
                      dtype=jnp.int32)
    return slab_bits, occ, row, payload_slabs, dropped


class BucketPlan:
    """Cached per-side hashing state threaded through sizing + kernel plans.

    Built once per table side from the (promoted) key columns: the int32
    bit-planes are extracted exactly once, and bucket ids are memoized per
    bucket count — so the eager two-pass sizing planner and the jitted
    kernel plan share one hash of the keys instead of re-hashing per
    phase.  Traced callers (jit / shard_map) skip :meth:`bucket_ids_for`
    and let the fused kernel hash in-pass.
    """

    __slots__ = ("bits", "valid", "_bid")

    def __init__(self, key_cols=None, valid=None, *, bits=None):
        self.bits = tuple(bits) if bits is not None \
            else tuple(key_bits(c) for c in key_cols)
        self.valid = valid
        self._bid = {}

    @property
    def concrete(self) -> bool:
        """True when the bit-planes are concrete (eager caller) — the
        host-side sizing planner only applies then."""
        return not any(isinstance(b, jax.core.Tracer) for b in self.bits)

    def bucket_ids_for(self, num_buckets: int) -> jnp.ndarray:
        """Full-capacity bucket ids for ``num_buckets``, memoized."""
        if num_buckets not in self._bid:
            self._bid[num_buckets] = bucket_ids(self.bits, num_buckets)
        return self._bid[num_buckets]


def default_bucket_count(capacity: int) -> int:
    """~16-rows-per-bucket power-of-two bucket count, capped at
    ``MAX_RADIX_BUCKETS`` (the single-pass ranking's one-hot width)."""
    target = max(1, capacity // 16)
    return 1 << min(MAX_RADIX_BUCKETS.bit_length() - 1,
                    max(3, (target - 1).bit_length()))


def plan_bucket_sizes(key_cols=None, num_buckets: int | None = None, *,
                      headroom: float = 1.25, min_capacity: int = 8,
                      plan: BucketPlan | None = None,
                      nvalid: int | None = None):
    """Two-pass (histogram, then size) bucket planner -> ``(num_buckets,
    slab_capacity)`` static sizes that are *distribution-proof* for the
    given keys.

    The one-pass auto-sizing heuristics assume ~uniform key spread above
    ``EXACT_SLAB_CAP``, so a heavily skewed key distribution can overflow
    its hottest bucket's slab.  This planner runs **host-side on concrete
    key columns** (valid rows only): pass 1 buckets the actual keys with
    the same ``bucket_ids`` hash the kernels use, pass 2 sizes the slab to
    the observed maximum bucket load (times ``headroom``, rounded up to a
    multiple of 8 for lane alignment) — the overflow counter is then zero
    by construction for these keys.  The default ``headroom`` keeps a
    small cushion above the observed max so a plan *reused* on slightly
    different keys (one more duplicate of the hottest key, the next chunk
    of the same stream) still fits; ``headroom=1.0`` sizes exactly to the
    observed keys.  Callers under ``jit``/``shard_map`` can't plan (the
    keys are traced); they keep the heuristic or pass explicit sizes.

    Pass a :class:`BucketPlan` (with ``nvalid``) instead of raw columns to
    reuse its already-extracted bit-planes and memoize the bucket ids for
    the kernel plan — valid rows are the table prefix, so slicing the
    full-capacity hash to ``[:nvalid]`` equals hashing the sliced keys.
    """
    if plan is not None:
        n = int(nvalid if nvalid is not None
                else (plan.bits[0].shape[0] if plan.bits else 0))
        if num_buckets is None:
            num_buckets = default_bucket_count(n)
        if n == 0:
            return num_buckets, min_capacity
        bid = np.asarray(plan.bucket_ids_for(num_buckets))[:n]
    else:
        cols = [np.asarray(c) for c in key_cols]
        n = int(cols[0].shape[0]) if cols else 0
        if num_buckets is None:
            num_buckets = default_bucket_count(n)
        if n == 0:
            return num_buckets, min_capacity
        bits = tuple(key_bits(jnp.asarray(c)) for c in cols)
        bid = np.asarray(bucket_ids(bits, num_buckets))
    load = int(np.bincount(bid, minlength=num_buckets).max())
    cap = int(math.ceil(load * headroom))
    return num_buckets, max(min_capacity, -(-cap // 8) * 8)
