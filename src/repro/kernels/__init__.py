"""Pallas TPU kernels: each subpackage has kernel.py (pl.pallas_call +
BlockSpec), ops.py (jit'd wrapper + backend dispatch), ref.py (pure-jnp
oracle used for interpret-mode validation)."""
from . import (flash_attention, hash_groupby, hash_join,  # noqa: F401
               hash_partition, hash_semi, mamba_scan, radix_sort)
