"""Pallas TPU kernels: each subpackage has kernel.py (pl.pallas_call +
BlockSpec), ops.py (jit'd wrapper + backend dispatch), ref.py (pure-jnp
oracle used for interpret-mode validation)."""
from . import (flash_attention, hash_join, hash_partition,  # noqa: F401
               mamba_scan)
