"""Pure-jnp oracle for the bucketed hash-join probe kernel.

Both join sides arrive already *bucket-grouped* (ops.py does the grouping
with the ``hash_partition`` radix ranks): for each of ``B`` buckets there
is a probe slab of ``Lc`` slots and a build slab of ``C`` slots, each slot
holding the row's key bit-planes (``K`` int32 planes per key) plus an
occupancy flag.  The probe computes, per bucket:

* ``counts`` — ``(B, Lc)`` int32 number of build matches per probe slot;
* ``rank``   — ``(B, Lc, C)`` int32 match rank of chain slot ``p`` within
  probe slot ``l``'s matches (exclusive count of earlier matching chain
  slots), or ``-1`` where the pair does not match.

A pair matches iff *all* key bit-planes are equal and both slots are
occupied.  Chain order is build-insertion order, which ops.py keeps equal
to original row order (stable radix ranks) — this is what makes the hash
join's output row order bit-identical to the sort-merge join's.
"""
import jax.numpy as jnp


def bucket_probe_ref(pbits: jnp.ndarray, pocc: jnp.ndarray,
                     bbits: jnp.ndarray, bocc: jnp.ndarray):
    """pbits (B, K, Lc) int32, pocc (B, Lc) int32 0/1, bbits (B, K, C),
    bocc (B, C) -> (counts (B, Lc) int32, rank (B, Lc, C) int32)."""
    match = (pocc[:, :, None] > 0) & (bocc[:, None, :] > 0)
    num_keys = pbits.shape[1]
    for k in range(num_keys):
        match = match & (pbits[:, k, :, None] == bbits[:, k, None, :])
    m = match.astype(jnp.int32)
    counts = jnp.sum(m, axis=2)
    excl = jnp.cumsum(m, axis=2) - m
    rank = jnp.where(match, excl, -1)
    return counts, rank
