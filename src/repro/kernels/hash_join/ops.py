"""Jitted bucketed hash-join build + probe plan.

:func:`hash_join_plan` is the op the table engine calls for
``join(impl="hash")``: it buckets both sides by a murmur-style key hash
(build side = the chain table, probe side = the left rows), runs the
bucketed probe (Pallas kernel on TPU, pure-jnp ref elsewhere) and returns
everything the caller needs to scatter matched pairs into a static-capacity
output: per-left-row match counts plus, per (probe slot, chain slot) pair,
the original row ids and the within-row match rank.

Static-shape contract (the same philosophy as the table shuffle): a bucket
holds at most ``bucket_capacity`` build rows and ``probe_capacity`` probe
rows.  Overflowing rows are dropped and *counted* (``build_dropped`` /
``probe_dropped``) — callers size the capacities so both are zero, and the
conformance suite checks the counters trip exactly at capacity.

The plan takes **key bit-planes**, not raw key columns: the engine
extracts them once per side (``bucketing.BucketPlan`` /
``bucketing.key_bits`` — floats bitcast to int32 after normalizing
``-0.0`` to ``+0.0``) and shares them with the host-side sizing pass, so
build and probe never re-hash the same columns.  Multi-column keys are
exact — the hash only picks the bucket; equality is decided on the full
key bits.  NaN float keys compare equal-by-bits (joins on NaN keys are
out of contract, as they are for the sort-merge path's sort order).
"""
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..bucketing import (EXACT_SLAB_CAP, bucket_ids,  # noqa: F401
                         group_to_slabs, key_bits)
from .kernel import bucket_probe_buckets
from .ref import bucket_probe_ref


def _group(bits: tuple, valid: jnp.ndarray, num_buckets: int,
           slab_cap: int, impl: str, bid=None):
    """Bucket-grouped slabs (see kernels.bucketing.group_to_slabs)."""
    slab_bits, occ, row, _, dropped = group_to_slabs(
        bits, valid, num_buckets, slab_cap, impl, bid=bid)
    return slab_bits, occ, row, dropped


class HashJoinPlan(NamedTuple):
    """Probe results mapped back to original row ids.

    ``match_counts`` is indexed by original left row (0 for padding rows
    and for probe-dropped rows); the pair-space arrays are indexed by
    (bucket, probe slot, chain slot) and carry original row ids.
    """

    match_counts: jnp.ndarray    # (Lcap,) int32
    probed: jnp.ndarray          # (Lcap,) bool: left row made it into a slab
    probe_row: jnp.ndarray       # (B, Lc) int32 original left row per slot
    rank: jnp.ndarray            # (B, Lc, C) int32 match rank, -1 = no match
    build_row: jnp.ndarray       # (B, C) int32 original right row per slot
    build_dropped: jnp.ndarray   # () int32 right rows lost to chain overflow
    probe_dropped: jnp.ndarray   # () int32 left rows lost to probe overflow


@functools.partial(jax.jit, static_argnames=("num_buckets",
                                             "bucket_capacity",
                                             "probe_capacity", "impl"))
def hash_join_plan(left_bits: tuple, left_valid: jnp.ndarray,
                   right_bits: tuple, right_valid: jnp.ndarray, *,
                   num_buckets: int, bucket_capacity: int,
                   probe_capacity: int, impl: str = "ref",
                   left_bid: jnp.ndarray | None = None,
                   right_bid: jnp.ndarray | None = None) -> HashJoinPlan:
    """Bucketed build (right) + probe (left) over parallel key bit-planes.

    impl: 'ref' (pure jnp), 'pallas' (TPU), 'pallas_interpret' (CPU check).
    ``left_bid`` / ``right_bid`` carry precomputed bucket ids (the eager
    sizing path's hash, via ``BucketPlan``) so the plan doesn't re-hash.
    """
    B, C, Lc = num_buckets, bucket_capacity, probe_capacity
    lbits, rbits = tuple(left_bits), tuple(right_bits)
    lcap = left_valid.shape[0]

    bslab, bocc, brow, build_dropped = _group(rbits, right_valid, B, C,
                                              impl, bid=right_bid)
    pslab, pocc, prow, probe_dropped = _group(lbits, left_valid, B, Lc,
                                              impl, bid=left_bid)

    num_keys = len(lbits)
    pb = pslab.reshape(num_keys, B, Lc).transpose(1, 0, 2)
    bb = bslab.reshape(num_keys, B, C).transpose(1, 0, 2)
    po = pocc.reshape(B, Lc)
    bo = bocc.reshape(B, C)
    if impl == "ref":
        counts_g, rank_g = bucket_probe_ref(pb, po, bb, bo)
    else:
        counts_g, rank_g = bucket_probe_buckets(
            pb, po, bb, bo, interpret=(impl == "pallas_interpret"))

    # counts + probed back to original left-row order in ONE stacked
    # scatter (trash slot lcap for empties)
    idx = jnp.where(pocc > 0, prow, lcap)
    packed = (jnp.zeros((2, lcap + 1), jnp.int32)
              .at[:, idx].set(jnp.stack([counts_g.reshape(-1),
                                         (pocc > 0).astype(jnp.int32)]))
              [:, :lcap])
    return HashJoinPlan(match_counts=packed[0], probed=packed[1] > 0,
                        probe_row=prow.reshape(B, Lc),
                        rank=rank_g,
                        build_row=brow.reshape(B, C),
                        build_dropped=build_dropped,
                        probe_dropped=probe_dropped)


def workload_hash_join_sizes(keys_per_shard: int, slab: int = 256) -> dict:
    """Bucket sizing for a known duplicate-heavy workload (the paper's
    10%-key-uniqueness joins): ~4 distinct keys (~40 rows at 10x
    duplication) per bucket on average, ``slab``-slot build/probe slabs
    (>6x headroom over the expected max bucket load).  Returns kwargs for
    ``local_ops.join`` / ``dist_join(local_join_sizes=...)``."""
    target = max(8, keys_per_shard // 4)
    num_buckets = 1 << max(0, int(target - 1).bit_length())
    return {"num_buckets": num_buckets, "bucket_capacity": slab,
            "probe_capacity": slab}


def default_hash_join_sizes(left_capacity: int, right_capacity: int,
                            num_buckets: int | None = None):
    """(num_buckets, bucket_capacity, probe_capacity) heuristics.

    Small tables (both capacities <= ``bucketing.EXACT_SLAB_CAP``) get
    full-capacity slabs: every key distribution — including all-equal
    keys — fits with zero overflow, so the env-default hash backend is
    exact wherever the sort-merge backend is.  Larger tables get ~16
    build rows per bucket on average with 4x headroom per slab — an
    assumption of ~uniform key spread; with *concrete* (non-traced) keys
    the engine upgrades the slab capacities to the distribution-proof
    two-pass ``bucketing.plan_bucket_sizes`` planner.  A caller-chosen
    ``num_buckets`` keeps the slab capacities consistent with *that*
    bucket count; size explicitly for skewed large-table keys under
    ``jit`` (the capacities are worst-case *per bucket*, so heavy
    duplication needs deeper, fewer buckets)."""
    small = max(left_capacity, right_capacity) <= EXACT_SLAB_CAP
    if num_buckets is None:
        if small:
            num_buckets = 8
        else:
            target = max(1, right_capacity // 16)
            num_buckets = 1 << min(16, max(3, (target - 1).bit_length()))
    if small:
        return num_buckets, max(8, right_capacity), max(8, left_capacity)
    chain = max(8, -(-right_capacity // num_buckets) * 4)
    probe = max(8, -(-left_capacity // num_buckets) * 4)
    return num_buckets, chain, probe
