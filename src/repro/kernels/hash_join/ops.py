"""Jitted bucketed hash-join build + probe plan.

:func:`hash_join_plan` is the op the table engine calls for
``join(impl="hash")``: it buckets both sides by a murmur-style key hash
(build side = the chain table, probe side = the left rows), runs the
bucketed probe (Pallas kernel on TPU, pure-jnp ref elsewhere) and returns
everything the caller needs to scatter matched pairs into a static-capacity
output: per-left-row match counts plus, per (probe slot, chain slot) pair,
the original row ids and the within-row match rank.

Static-shape contract (the same philosophy as the table shuffle): a bucket
holds at most ``bucket_capacity`` build rows and ``probe_capacity`` probe
rows.  Overflowing rows are dropped and *counted* (``build_dropped`` /
``probe_dropped``) — callers size the capacities so both are zero, and the
conformance suite checks the counters trip exactly at capacity.

Keys are compared as int32 bit-planes (floats are bitcast after
normalizing ``-0.0`` to ``+0.0``), so multi-column keys are exact — the
hash only picks the bucket; equality is decided on the full key bits.
NaN float keys compare equal-by-bits (joins on NaN keys are out of
contract, as they are for the sort-merge path's sort order).
"""
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..hash_partition import radix_histogram_ranks
from .kernel import bucket_probe_buckets
from .ref import bucket_probe_ref

# the radix ref/kernel materializes an (n, P) one-hot; past ~512 buckets
# fall back to a sort-based ranking (a TPU build would multi-pass instead)
_MAX_RADIX_BUCKETS = 512


def key_bits(col: jnp.ndarray) -> jnp.ndarray:
    """Key column -> int32 bit-plane with exact equality semantics."""
    if jnp.issubdtype(col.dtype, jnp.floating):
        col = col.astype(jnp.float32)
        col = jnp.where(col == 0.0, jnp.zeros_like(col), col)  # -0.0 == 0.0
        return jax.lax.bitcast_convert_type(col, jnp.int32)
    return col.astype(jnp.int32)


def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 over uint32 (same family as core.partition)."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def bucket_ids(bits: tuple, num_buckets: int) -> jnp.ndarray:
    """Combined bucket id over key bit-planes (equal keys -> equal bucket)."""
    h = jnp.full(bits[0].shape, jnp.uint32(0x9E3779B9))
    for b in bits:
        u = jax.lax.bitcast_convert_type(b, jnp.uint32)
        h = _mix32(h ^ (u + jnp.uint32(0x9E3779B9) + (h << 6) + (h >> 2)))
    return (h % jnp.uint32(num_buckets)).astype(jnp.int32)


def _bucket_ranks(bid: jnp.ndarray, num_buckets: int, impl: str):
    """(hist (P,), stable within-bucket ranks (n,)) for P = num_buckets."""
    if num_buckets <= _MAX_RADIX_BUCKETS:
        return radix_histogram_ranks(bid, num_buckets, impl=impl)
    hist = jnp.zeros((num_buckets,), jnp.int32).at[bid].add(1)
    order = jnp.argsort(bid, stable=True)
    sorted_bid = bid[order]
    n = bid.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    boundary = (iota == 0) | (sorted_bid != jnp.roll(sorted_bid, 1))
    start = jax.lax.associative_scan(jnp.maximum,
                                     jnp.where(boundary, iota, 0))
    ranks = jnp.zeros((n,), jnp.int32).at[order].set(iota - start)
    return hist, ranks


def _group(bits: tuple, valid: jnp.ndarray, num_buckets: int,
           slab_cap: int, impl: str):
    """Scatter rows into (num_buckets * slab_cap) bucket-grouped slots.

    Returns (slab_bits (K, B*cap), occ (B*cap,), row (B*cap,), dropped).
    Slot order within a bucket is original row order (stable ranks).
    """
    cap = valid.shape[0]
    bid = jnp.where(valid, bucket_ids(bits, num_buckets), num_buckets)
    hist, ranks = _bucket_ranks(bid, num_buckets + 1, impl)
    ok = valid & (ranks < slab_cap) & (bid < num_buckets)
    nslots = num_buckets * slab_cap
    slot = jnp.where(ok, bid * slab_cap + ranks, nslots)

    def scat(col):
        return jnp.zeros((nslots + 1,), col.dtype).at[slot].set(col)[:nslots]

    slab_bits = jnp.stack([scat(b) for b in bits])
    occ = scat(ok.astype(jnp.int32))
    row = scat(jnp.arange(cap, dtype=jnp.int32))
    dropped = jnp.sum(jnp.maximum(hist[:num_buckets] - slab_cap, 0),
                      dtype=jnp.int32)
    return slab_bits, occ, row, dropped


class HashJoinPlan(NamedTuple):
    """Probe results mapped back to original row ids.

    ``match_counts`` is indexed by original left row (0 for padding rows
    and for probe-dropped rows); the pair-space arrays are indexed by
    (bucket, probe slot, chain slot) and carry original row ids.
    """

    match_counts: jnp.ndarray    # (Lcap,) int32
    probed: jnp.ndarray          # (Lcap,) bool: left row made it into a slab
    probe_row: jnp.ndarray       # (B, Lc) int32 original left row per slot
    rank: jnp.ndarray            # (B, Lc, C) int32 match rank, -1 = no match
    build_row: jnp.ndarray       # (B, C) int32 original right row per slot
    build_dropped: jnp.ndarray   # () int32 right rows lost to chain overflow
    probe_dropped: jnp.ndarray   # () int32 left rows lost to probe overflow


@functools.partial(jax.jit, static_argnames=("num_buckets",
                                             "bucket_capacity",
                                             "probe_capacity", "impl"))
def hash_join_plan(left_keys: tuple, left_valid: jnp.ndarray,
                   right_keys: tuple, right_valid: jnp.ndarray, *,
                   num_buckets: int, bucket_capacity: int,
                   probe_capacity: int, impl: str = "ref") -> HashJoinPlan:
    """Bucketed build (right) + probe (left) over parallel key columns.

    impl: 'ref' (pure jnp), 'pallas' (TPU), 'pallas_interpret' (CPU check).
    """
    B, C, Lc = num_buckets, bucket_capacity, probe_capacity
    lbits = tuple(key_bits(c) for c in left_keys)
    rbits = tuple(key_bits(c) for c in right_keys)
    lcap = left_valid.shape[0]

    bslab, bocc, brow, build_dropped = _group(rbits, right_valid, B, C, impl)
    pslab, pocc, prow, probe_dropped = _group(lbits, left_valid, B, Lc, impl)

    num_keys = len(lbits)
    pb = pslab.reshape(num_keys, B, Lc).transpose(1, 0, 2)
    bb = bslab.reshape(num_keys, B, C).transpose(1, 0, 2)
    po = pocc.reshape(B, Lc)
    bo = bocc.reshape(B, C)
    if impl == "ref":
        counts_g, rank_g = bucket_probe_ref(pb, po, bb, bo)
    else:
        counts_g, rank_g = bucket_probe_buckets(
            pb, po, bb, bo, interpret=(impl == "pallas_interpret"))

    # counts back to original left-row order (trash slot lcap for empties)
    idx = jnp.where(pocc > 0, prow, lcap)
    match_counts = (jnp.zeros((lcap + 1,), jnp.int32)
                    .at[idx].set(counts_g.reshape(-1))[:lcap])
    probed = (jnp.zeros((lcap + 1,), bool)
              .at[idx].set(pocc > 0)[:lcap])
    return HashJoinPlan(match_counts=match_counts, probed=probed,
                        probe_row=prow.reshape(B, Lc),
                        rank=rank_g,
                        build_row=brow.reshape(B, C),
                        build_dropped=build_dropped,
                        probe_dropped=probe_dropped)


def workload_hash_join_sizes(keys_per_shard: int, slab: int = 256) -> dict:
    """Bucket sizing for a known duplicate-heavy workload (the paper's
    10%-key-uniqueness joins): ~4 distinct keys (~40 rows at 10x
    duplication) per bucket on average, ``slab``-slot build/probe slabs
    (>6x headroom over the expected max bucket load).  Returns kwargs for
    ``local_ops.join`` / ``dist_join(local_join_sizes=...)``."""
    target = max(8, keys_per_shard // 4)
    num_buckets = 1 << max(0, int(target - 1).bit_length())
    return {"num_buckets": num_buckets, "bucket_capacity": slab,
            "probe_capacity": slab}


def default_hash_join_sizes(left_capacity: int, right_capacity: int,
                            num_buckets: int | None = None):
    """(num_buckets, bucket_capacity, probe_capacity) heuristics: ~16 build
    rows per bucket on average with 4x headroom per slab; a caller-chosen
    ``num_buckets`` keeps the slab capacities consistent with *that* bucket
    count.  Size explicitly for skewed key distributions (the capacities
    are worst-case *per bucket*, so heavy duplication needs deeper, fewer
    buckets)."""
    if num_buckets is None:
        target = max(1, right_capacity // 16)
        num_buckets = 1 << min(16, max(3, (target - 1).bit_length()))
    chain = max(8, -(-right_capacity // num_buckets) * 4)
    probe = max(8, -(-left_capacity // num_buckets) * 4)
    return num_buckets, chain, probe
