from .ops import (HashJoinPlan, default_hash_join_sizes,  # noqa: F401
                  hash_join_plan, workload_hash_join_sizes)
