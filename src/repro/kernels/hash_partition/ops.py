"""Jitted wrapper around the radix histogram/rank kernel.

``partition_plan`` is the op the table engine and the MoE layer both call:
given per-row partition ids it returns, for every row, a stable destination
slot ``dest = global_offset[pid] + rank_within_pid`` plus the per-partition
histogram — i.e. everything needed to scatter rows into partition-grouped
order (table Shuffle) or into per-expert buckets (MoE dispatch).
"""
import functools

import jax
import jax.numpy as jnp

from .. import autotune
from .kernel import radix_histogram_ranks_tiles
from .ref import radix_histogram_ranks_ref

_DEFAULT_TILE = 1024


@functools.partial(jax.jit, static_argnames=("num_partitions", "impl", "tile"))
def _radix_histogram_ranks(pid: jnp.ndarray, num_partitions: int,
                           impl: str = "ref", tile: int = _DEFAULT_TILE):
    n = pid.shape[0]
    if impl == "ref" or n < tile:
        return radix_histogram_ranks_ref(pid, num_partitions)

    n_tiles = -(-n // tile)
    pad = n_tiles * tile - n
    # pad with partition id P (an extra, ignored bucket would break the
    # one-hot width) -> use id 0 but mask ranks/hist afterwards via a
    # sentinel-free approach: pad ids with 0 and subtract the pad rows'
    # contribution from hist[0]; pad rows sit at the tail so their ranks
    # never collide with real rows' dest slots once masked by callers.
    pid_p = jnp.pad(pid, (0, pad), constant_values=0)
    tiles = pid_p.reshape(n_tiles, tile)
    hist_t, rank_t = radix_histogram_ranks_tiles(
        tiles, num_partitions,
        interpret=(impl == "pallas_interpret"))
    # cross-tile exclusive scan: rank of row in tile t = within-tile rank
    # + sum of matching counts in earlier tiles.
    tile_offsets = jnp.cumsum(hist_t, axis=0) - hist_t      # (n_tiles, P)
    ranks = (rank_t + jnp.take_along_axis(
        tile_offsets, tiles, axis=1)).reshape(-1)[:n]
    hist = jnp.sum(hist_t, axis=0).at[0].add(-pad)
    return hist, ranks


def radix_histogram_ranks(pid: jnp.ndarray, num_partitions: int,
                          impl: str = "ref", tile: int | None = None):
    """hist (P,), ranks (n,) — stable within-partition ranks.

    impl: 'ref' (pure jnp), 'pallas' (TPU), 'pallas_interpret' (CPU check).
    ``tile=None`` resolves through the autotuner (``REPRO_TILE`` override).
    """
    if tile is None:
        tile = autotune.tuned("tile", impl, pid.shape[0])
    return _radix_histogram_ranks(pid, num_partitions, impl=impl, tile=tile)


@functools.partial(jax.jit, static_argnames=("num_partitions", "impl", "tile"))
def _partition_plan(pid: jnp.ndarray, num_partitions: int,
                    impl: str = "ref", tile: int = _DEFAULT_TILE):
    hist, ranks = _radix_histogram_ranks(pid, num_partitions, impl=impl,
                                         tile=tile)
    offsets = jnp.cumsum(hist) - hist
    return hist, offsets[pid] + ranks


def partition_plan(pid: jnp.ndarray, num_partitions: int,
                   impl: str = "ref", tile: int | None = None):
    """(hist, dest): dest[i] = exclusive_offset[pid[i]] + rank[i].

    Scattering row i to slot ``dest[i]`` groups rows by partition, stable
    within each partition (exactly Cylon's hash-partition layout).
    ``tile=None`` resolves through the autotuner (``REPRO_TILE`` override).
    """
    if tile is None:
        tile = autotune.tuned("tile", impl, pid.shape[0])
    return _partition_plan(pid, num_partitions, impl=impl, tile=tile)
