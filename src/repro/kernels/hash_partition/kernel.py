"""Pallas TPU radix histogram + within-tile rank kernel.

Tiling: the row axis is blocked into ``(n_tiles, tile)``; each grid step
loads one ``(1, tile)`` slab of partition ids into VMEM, materializes the
``(tile, P)`` one-hot occupancy matrix in VREGs and reduces it two ways:

* per-tile histogram  ``(1, P)``      (sum over rows), and
* within-tile ranks   ``(1, tile)``   (exclusive cumsum over rows, gathered
  at each row's own partition column).

The cross-tile exclusive scan (cheap, ``(n_tiles, P)``) is composed outside
the kernel in ``ops.py`` — keeping the kernel embarrassingly parallel over
tiles (``dimension_semantics=("parallel",)``).

VMEM budget: tile=1024, P<=512 -> one-hot is 1024*512*4 B = 2 MiB, well
under the ~16 MiB/core VMEM of TPU v5e.  ``tile`` and ``P`` are both
hardware-aligned (multiples of 128 recommended).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ..compat import TPUCompilerParams


def _kernel(pid_ref, hist_ref, rank_ref, *, num_partitions: int):
    pid = pid_ref[0, :]                                    # (tile,)
    tile = pid.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (tile, num_partitions), 1)
    onehot = (pid[:, None] == cols).astype(jnp.int32)      # (tile, P)
    hist_ref[0, :] = jnp.sum(onehot, axis=0)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    rank_ref[0, :] = jnp.sum(excl * onehot, axis=1)


def radix_histogram_ranks_tiles(pid_tiles: jnp.ndarray, num_partitions: int,
                                *, interpret: bool = False):
    """``pid_tiles``: int32 ``(n_tiles, tile)`` -> (hist ``(n_tiles, P)``,
    ranks ``(n_tiles, tile)``)."""
    n_tiles, tile = pid_tiles.shape
    kern = functools.partial(_kernel, num_partitions=num_partitions)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = TPUCompilerParams(
            dimension_semantics=("parallel",))
    return pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, num_partitions), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, num_partitions), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, tile), jnp.int32),
        ],
        interpret=interpret,
        **kwargs,
    )(pid_tiles)
