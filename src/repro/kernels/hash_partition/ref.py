"""Pure-jnp oracle for the radix histogram/rank kernel.

Given partition ids ``pid`` (int32 ``(n,)`` in ``[0, num_partitions)``),
produce:

* ``hist``  — ``(num_partitions,)`` int32 row counts per partition;
* ``ranks`` — ``(n,)`` int32 stable rank of each row *within* its partition
  (the i-th row with pid p gets rank i, in original row order).

This is the compute hot-spot of the HPTMT table Shuffle (Cylon's hash
partitioning) and of MoE token dispatch — both are "scatter rows into
buckets" (DESIGN.md §2).
"""
import jax.numpy as jnp


def radix_histogram_ranks_ref(pid: jnp.ndarray, num_partitions: int):
    onehot = (pid[:, None] == jnp.arange(num_partitions, dtype=pid.dtype)
              [None, :]).astype(jnp.int32)
    hist = jnp.sum(onehot, axis=0)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    ranks = jnp.sum(excl * onehot, axis=1)
    return hist, ranks
