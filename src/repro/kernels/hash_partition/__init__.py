from .ops import partition_plan, radix_histogram_ranks  # noqa: F401
