"""jax API-drift shims shared by the kernel packages.

jax renamed the Pallas TPU compiler-params dataclass — newer releases
expose ``pltpu.CompilerParams``, the pinned 0.4.x line only the older
``pltpu.TPUCompilerParams``.  Resolve whichever exists once, here (the
same shim idea as ``core/context.py``'s shard_map import).  Only the
non-interpret TPU path ever instantiates it, so interpret-mode CI cannot
catch a bad name — keep all kernels on this alias.
"""
from jax.experimental.pallas import tpu as pltpu

TPUCompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
