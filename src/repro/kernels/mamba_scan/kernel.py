"""Pallas TPU selective-scan kernel (Mamba-1).

TPU adaptation of the CUDA selective-scan: instead of warp-level parallel
prefix products, the channel axis E is blocked over a *parallel* grid
dimension (each (batch, channel-block) pair is an independent recurrence)
and time is blocked over an *arbitrary* (sequential) grid dimension with
the SSM state ``h (be, N)`` carried across chunks in VMEM scratch.  Inside
one time chunk the recurrence runs as a ``fori_loop`` over VREG-resident
slices — HBM traffic is exactly one read of (x, delta, B, C) and one write
of y per token, the roofline optimum for this memory-bound op.

VMEM: chunk=256, be=256, N=16 -> x/delta/y slabs 3*256*256*4 = 768 KiB,
B/C 2*256*16*4 = 32 KiB, h 256*16*4 = 16 KiB.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ..compat import TPUCompilerParams


def _kernel(x_ref, d_ref, A_ref, B_ref, C_ref, D_ref, y_ref, h_ref, *,
            chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_ref[:] = jnp.zeros_like(h_ref)

    A = A_ref[:]                                   # (be, N)
    Dd = D_ref[:]                                  # (1, be)

    def step(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)    # (be,)
        dt = d_ref[0, t, :].astype(jnp.float32)    # (be,)
        bt = B_ref[0, t, :].astype(jnp.float32)    # (N,)
        ct = C_ref[0, t, :].astype(jnp.float32)    # (N,)
        dA = jnp.exp(dt[:, None] * A)              # (be, N)
        h = dA * h + (dt * xt)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=1) + Dd[0] * xt
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h_ref[:] = jax.lax.fori_loop(0, chunk, step, h_ref[:])


def selective_scan_pallas(x, delta, A, Bm, Cm, D, *, be: int = 256,
                          chunk: int = 256, interpret: bool = False):
    Bsz, S, E = x.shape
    N = A.shape[1]
    be = min(be, E)
    chunk = min(chunk, S)
    assert E % be == 0 and S % chunk == 0
    grid = (Bsz, E // be, S // chunk)
    D2 = D.reshape(1, E)

    kern = functools.partial(_kernel, chunk=chunk)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    y = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, be), lambda b, e, c: (b, c, e)),   # x
            pl.BlockSpec((1, chunk, be), lambda b, e, c: (b, c, e)),   # delta
            pl.BlockSpec((be, N), lambda b, e, c: (e, 0)),             # A
            pl.BlockSpec((1, chunk, N), lambda b, e, c: (b, c, 0)),    # B
            pl.BlockSpec((1, chunk, N), lambda b, e, c: (b, c, 0)),    # C
            pl.BlockSpec((1, be), lambda b, e, c: (0, e)),             # D
        ],
        out_specs=pl.BlockSpec((1, chunk, be), lambda b, e, c: (b, c, e)),
        out_shape=jax.ShapeDtypeStruct((Bsz, S, E), x.dtype),
        scratch_shapes=[pltpu.VMEM((be, N), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(x, delta, A, Bm, Cm, D2)
    return y
