"""Pure-jnp oracle for the Mamba-1 selective scan.

Discretization (Mamba paper, ZOH for A / Euler for B):
    dA_t = exp(softplus-free delta_t * A)           (delta already softplus'd)
    h_t  = dA_t * h_{t-1} + (delta_t * x_t) B_t
    y_t  = <h_t, C_t> + D * x_t
Shapes: x,delta (B,S,E); A (E,N); Bm,Cm (B,S,N); D (E,) -> y (B,S,E).
"""
import jax
import jax.numpy as jnp


def selective_scan_ref(x, delta, A, Bm, Cm, D, h0=None):
    Bsz, S, E = x.shape
    N = A.shape[1]
    xf = x.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(h, inp):
        xt, dt, bt, ct = inp                       # (B,E),(B,E),(B,N),(B,N)
        dA = jnp.exp(dt[..., None] * Af[None])     # (B,E,N)
        dBx = (dt * xt)[..., None] * bt[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("ben,bn->be", h, ct)
        return h, y

    h0 = h0 if h0 is not None else jnp.zeros((Bsz, E, N), jnp.float32)
    hT, ys = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(df, 1, 0),
         jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1) + xf * D.astype(jnp.float32)[None, None]
    return y.astype(x.dtype), hT
