"""Jitted wrapper for the selective-scan kernel with backend dispatch."""
import functools

import jax

from .kernel import selective_scan_pallas
from .ref import selective_scan_ref


@functools.partial(jax.jit, static_argnames=("impl", "be", "chunk"))
def selective_scan(x, delta, A, Bm, Cm, D, *, impl: str = "ref",
                   be: int = 256, chunk: int = 256):
    """Mamba-1 selective scan.  impl: 'ref' | 'pallas' | 'pallas_interpret'.

    Returns y (B,S,E).  (The ref additionally returns the final state; the
    kernel path recomputes it on demand — decode uses the step form in
    ``repro.models.mamba``.)
    """
    if impl == "ref":
        return selective_scan_ref(x, delta, A, Bm, Cm, D)[0]
    return selective_scan_pallas(x, delta, A, Bm, Cm, D, be=be, chunk=chunk,
                                 interpret=(impl == "pallas_interpret"))
