"""Pure-jnp oracle for one LSD radix digit pass.

A stable LSD radix sort is a chain of counting-sort passes.  Each pass
needs, for the ``radix_bits``-wide digit at bit offset ``shift`` of every
row's *sort word* (see ``ops.sortable_word``):

* ``hist``  — ``(2**radix_bits,)`` int32 row counts per digit value;
* ``ranks`` — ``(n,)`` int32 stable rank of each row *within* its digit
  (the i-th row carrying digit d gets rank i, in current row order).

Scattering row i to ``exclusive_offset[digit[i]] + ranks[i]`` is then one
stable counting-sort step.  Digit extraction is fused here (and in the
Pallas kernel) so a pass reads each word exactly once: arithmetic shift
plus mask is exact for every offset because the mask discards the
sign-extension bits.
"""
import jax.numpy as jnp


def extract_digits(words: jnp.ndarray, shift: int,
                   radix_bits: int) -> jnp.ndarray:
    """int32 sort words -> int32 digit in [0, 2**radix_bits)."""
    return (words >> shift) & jnp.int32((1 << radix_bits) - 1)


def digit_histogram_ranks_ref(words: jnp.ndarray, shift: int,
                              radix_bits: int):
    num_digits = 1 << radix_bits
    d = extract_digits(words, shift, radix_bits)
    onehot = (d[:, None] == jnp.arange(num_digits, dtype=jnp.int32)
              [None, :]).astype(jnp.int32)
    hist = jnp.sum(onehot, axis=0)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    ranks = jnp.sum(excl * onehot, axis=1)
    return hist, ranks
