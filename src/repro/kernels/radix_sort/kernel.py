"""Pallas TPU radix digit histogram + within-tile rank kernel.

The hot loop of one LSD radix pass.  Tiling mirrors the ``hash_partition``
kernel: the row axis is blocked into ``(n_tiles, tile)``; each grid step
loads one ``(1, tile)`` slab of int32 *sort words* into VMEM, extracts the
``radix_bits``-wide digit at ``shift`` in VREGs (arithmetic shift + mask —
exact at every offset because the mask discards sign-extension bits),
materializes the ``(tile, D)`` one-hot digit occupancy and reduces it two
ways:

* per-tile digit histogram  ``(1, D)``    (sum over rows), and
* within-tile digit ranks   ``(1, tile)`` (exclusive cumsum over rows,
  gathered at each row's own digit column).

Fusing digit extraction into the kernel means a pass streams each word
through VMEM exactly once; the cross-tile exclusive scan (cheap,
``(n_tiles, D)``) is composed outside in ``ops.py``, keeping the kernel
embarrassingly parallel over tiles.

VMEM budget: tile=1024, D=256 (the 8-bit default) -> one-hot is
1024*256*4 B = 1 MiB, well under ~16 MiB/core; the 1-bit compaction fast
path (D=2) is a sliver.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ..compat import TPUCompilerParams


def _kernel(words_ref, hist_ref, rank_ref, *, shift: int, radix_bits: int):
    words = words_ref[0, :]                                # (tile,)
    tile = words.shape[0]
    num_digits = 1 << radix_bits
    d = (words >> shift) & jnp.int32(num_digits - 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (tile, num_digits), 1)
    onehot = (d[:, None] == cols).astype(jnp.int32)        # (tile, D)
    hist_ref[0, :] = jnp.sum(onehot, axis=0)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    rank_ref[0, :] = jnp.sum(excl * onehot, axis=1)


def digit_histogram_ranks_tiles(word_tiles: jnp.ndarray, shift: int,
                                radix_bits: int, *,
                                interpret: bool = False):
    """``word_tiles``: int32 ``(n_tiles, tile)`` -> (hist ``(n_tiles, D)``,
    ranks ``(n_tiles, tile)``) for ``D = 2**radix_bits``."""
    n_tiles, tile = word_tiles.shape
    num_digits = 1 << radix_bits
    kern = functools.partial(_kernel, shift=shift, radix_bits=radix_bits)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = TPUCompilerParams(
            dimension_semantics=("parallel",))
    return pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, num_digits), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, num_digits), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, tile), jnp.int32),
        ],
        interpret=interpret,
        **kwargs,
    )(word_tiles)
