"""Multi-pass LSD radix sort/rank engine (kernel/ops/ref, see README)."""
from .ops import (DEFAULT_RADIX_BITS, grouped_ranks,  # noqa: F401
                  radix_permutation, radix_rank, sortable_word,
                  stable_partition_perm)
