"""Jitted multi-pass LSD radix rank/permutation engine.

The missing primitive behind every sort-shaped table operator: a *stable
rank* of each row under multi-key lexicographic order, computed as a chain
of counting-sort digit passes (``kernel.py`` on TPU, ``ref.py`` elsewhere)
— **no ``sort`` primitive anywhere in the jaxpr**.

Key columns are first mapped to int32 *sort words* whose unsigned order
equals ``jax.lax.sort``'s ascending order (:func:`sortable_word`): int32
gets the sign-bit bias; float32 follows XLA's total-order comparator —
``-0.0`` and ``0.0`` canonicalized equal, all NaNs canonicalized equal and
greatest — so the induced permutation is *bit-identical* to a stable
``jax.lax.sort`` over the same keys (descending keys are pre-transformed
by the caller, exactly like the XLA backend).  Each word then takes
``32 / radix_bits`` stable passes, least-significant digit first, followed
by a final 1-bit validity pass that moves padding rows to the end.

Public ops:

* :func:`radix_permutation` — the stable gather index (``out[i] =
  rows[perm[i]]``), drop-in for ``jax.lax.sort``'s iota payload;
* :func:`radix_rank` — its inverse (each row's output position);
* :func:`stable_partition_perm` — the 1-bit fast path: one pass over a
  boolean, bit-identical to ``argsort(~keep, stable=True)`` — the
  ``compact()``/shuffle-compaction hot loop;
* :func:`grouped_ranks` — (hist, stable within-partition ranks) for *any*
  partition count: the multi-pass generalization of
  ``hash_partition.radix_histogram_ranks`` (whose one-hot caps at
  ``bucketing.MAX_RADIX_BUCKETS``).
"""
import functools

import jax
import jax.numpy as jnp

from .. import autotune
from .kernel import digit_histogram_ranks_tiles
from .ref import digit_histogram_ranks_ref, extract_digits

# historical defaults — the public ops now resolve ``radix_bits``/``tile``
# through ``kernels.autotune`` (per-backend cache, ``REPRO_RADIX_BITS`` /
# ``REPRO_TILE`` overrides, optional first-use measurement sweep); these
# constants remain the autotuner's fallback values.
_DEFAULT_TILE = 1024
DEFAULT_RADIX_BITS = 8

_SIGN = jnp.int32(-2 ** 31)


def sortable_word(col: jnp.ndarray) -> jnp.ndarray:
    """Key column -> int32 word; unsigned word order == lax.sort order.

    Floats replicate XLA's sort comparator canonicalization: ``-0.0`` ==
    ``0.0`` and every NaN equal (and greatest), so ties keep original row
    order under the stable passes — exactly ``lax.sort``'s behavior.
    """
    if jnp.issubdtype(col.dtype, jnp.floating):
        col = col.astype(jnp.float32)
        col = jnp.where(col == 0.0, jnp.zeros_like(col), col)
        col = jnp.where(jnp.isnan(col), jnp.full_like(col, jnp.nan), col)
        bits = jax.lax.bitcast_convert_type(col, jnp.int32)
        # sign-magnitude -> biased two's complement: negative floats flip
        # all bits, non-negative flip only the sign bit
        return jnp.where(bits < 0, ~bits, bits ^ _SIGN)
    return col.astype(jnp.int32) ^ _SIGN


def _digit_pass(words: jnp.ndarray, shift: int, radix_bits: int,
                impl: str, tile: int):
    """(hist (D,), stable within-digit ranks (n,)) for one pass."""
    n = words.shape[0]
    if impl == "ref" or n < tile:
        return digit_histogram_ranks_ref(words, shift, radix_bits)
    n_tiles = -(-n // tile)
    pad = n_tiles * tile - n
    # pad word 0 has digit 0 at every shift; pad rows sit at the tail so
    # real rows' cross-tile offsets are unaffected — only hist[0] needs
    # the pad contribution subtracted.
    tiles = jnp.pad(words, (0, pad)).reshape(n_tiles, tile)
    hist_t, rank_t = digit_histogram_ranks_tiles(
        tiles, shift, radix_bits,
        interpret=(impl == "pallas_interpret"))
    tile_offsets = jnp.cumsum(hist_t, axis=0) - hist_t    # (n_tiles, D)
    d_tiles = extract_digits(tiles, shift, radix_bits)
    ranks = (rank_t + jnp.take_along_axis(
        tile_offsets, d_tiles, axis=1)).reshape(-1)[:n]
    hist = jnp.sum(hist_t, axis=0).at[0].add(-pad)
    return hist, ranks


def _scatter_pass(perm: jnp.ndarray, words: jnp.ndarray, shift: int,
                  radix_bits: int, impl: str, tile: int) -> jnp.ndarray:
    """One stable counting-sort pass: ``words`` are the current-order sort
    words (already gathered through ``perm``); returns the refined perm."""
    n = perm.shape[0]
    d = extract_digits(words, shift, radix_bits)
    hist, ranks = _digit_pass(words, shift, radix_bits, impl, tile)
    offsets = jnp.cumsum(hist) - hist
    dest = offsets[d] + ranks
    return jnp.zeros((n,), jnp.int32).at[dest].set(perm)


@functools.partial(jax.jit,
                   static_argnames=("impl", "radix_bits", "tile"))
def _radix_permutation(cols: tuple, invalid: jnp.ndarray, *,
                       impl: str = "ref",
                       radix_bits: int = DEFAULT_RADIX_BITS,
                       tile: int = _DEFAULT_TILE) -> jnp.ndarray:
    n = invalid.shape[0]
    perm = jnp.arange(n, dtype=jnp.int32)
    for col in reversed(cols):                 # least-significant key first
        w = sortable_word(col)
        for shift in range(0, 32, radix_bits):
            perm = _scatter_pass(perm, w[perm], shift, radix_bits, impl,
                                 tile)
    # most-significant: validity (padding rows move to the end, stably)
    flag = invalid[perm].astype(jnp.int32)
    return _scatter_pass(perm, flag, 0, 1, impl, tile)


def radix_permutation(cols: tuple, invalid: jnp.ndarray, *,
                      impl: str = "ref", radix_bits: int | None = None,
                      tile: int | None = None) -> jnp.ndarray:
    """Stable gather index sorting by ``cols`` lexicographically ascending,
    rows with ``invalid`` set last — bit-identical to the permutation of a
    stable ``lax.sort((invalid, *cols, iota))``.

    impl: 'ref' (pure jnp), 'pallas' (TPU), 'pallas_interpret' (CPU check).
    ``radix_bits``/``tile`` default to the autotuner's choice for this
    backend and size class (``REPRO_RADIX_BITS``/``REPRO_TILE`` override).
    """
    radix_bits, tile = autotune.radix_params(impl, invalid.shape[0],
                                             radix_bits, tile)
    return _radix_permutation(tuple(cols), invalid, impl=impl,
                              radix_bits=radix_bits, tile=tile)


@functools.partial(jax.jit,
                   static_argnames=("impl", "radix_bits", "tile"))
def _radix_rank(cols: tuple, invalid: jnp.ndarray, *, impl: str,
                radix_bits: int, tile: int) -> jnp.ndarray:
    n = invalid.shape[0]
    perm = _radix_permutation(cols, invalid, impl=impl,
                              radix_bits=radix_bits, tile=tile)
    iota = jnp.arange(n, dtype=jnp.int32)
    return jnp.zeros((n,), jnp.int32).at[perm].set(iota)


def radix_rank(cols: tuple, invalid: jnp.ndarray, *, impl: str = "ref",
               radix_bits: int | None = None,
               tile: int | None = None) -> jnp.ndarray:
    """Each row's stable output position under the same order (the inverse
    of :func:`radix_permutation`): valid rows with globally distinct keys
    get exactly their canonical (key-sorted) slot in ``[0, n_valid)``."""
    radix_bits, tile = autotune.radix_params(impl, invalid.shape[0],
                                             radix_bits, tile)
    return _radix_rank(tuple(cols), invalid, impl=impl,
                       radix_bits=radix_bits, tile=tile)


@functools.partial(jax.jit, static_argnames=("impl", "tile"))
def _stable_partition_perm(keep: jnp.ndarray, *, impl: str,
                           tile: int) -> jnp.ndarray:
    n = keep.shape[0]
    perm = jnp.arange(n, dtype=jnp.int32)
    flag = jnp.logical_not(keep).astype(jnp.int32)
    return _scatter_pass(perm, flag, 0, 1, impl, tile)


def stable_partition_perm(keep: jnp.ndarray, *, impl: str = "ref",
                          tile: int | None = None) -> jnp.ndarray:
    """1-bit fast path: gather index moving ``keep`` rows to the front,
    stable — bit-identical to ``argsort(~keep, stable=True)`` in a single
    counting pass (the compaction hot loop of ``compact()``/``select()``
    and the shuffle's receive side)."""
    if tile is None:
        tile = autotune.tuned("tile", impl, keep.shape[0])
    return _stable_partition_perm(keep, impl=impl, tile=tile)


@functools.partial(jax.jit,
                   static_argnames=("num_partitions", "impl", "radix_bits",
                                    "tile"))
def _grouped_ranks(pid: jnp.ndarray, num_partitions: int, *,
                   impl: str, radix_bits: int, tile: int):
    """(hist (P,), stable within-partition ranks (n,)) for any ``P``.

    The histogram is one scatter-add; ranks come from the global stable
    rank under ascending ``pid`` (``ceil(log2 P / radix_bits)`` digit
    passes over the id bits) minus the partition's exclusive offset —
    semantics identical to ``hash_partition.radix_histogram_ranks`` but
    with per-pass one-hot width ``2**radix_bits`` instead of ``P``, so
    large partition counts stay sort-free.
    """
    n = pid.shape[0]
    hist = jnp.zeros((num_partitions,), jnp.int32).at[pid].add(1)
    nbits = max(1, (num_partitions - 1).bit_length())
    perm = jnp.arange(n, dtype=jnp.int32)
    for shift in range(0, nbits, radix_bits):
        perm = _scatter_pass(perm, pid[perm], shift, radix_bits, impl,
                             tile)
    iota = jnp.arange(n, dtype=jnp.int32)
    grank = jnp.zeros((n,), jnp.int32).at[perm].set(iota)
    offsets = jnp.cumsum(hist) - hist
    return hist, grank - offsets[pid]


def grouped_ranks(pid: jnp.ndarray, num_partitions: int, *,
                  impl: str = "ref", radix_bits: int | None = None,
                  tile: int | None = None):
    """(hist (P,), stable within-partition ranks (n,)) for any ``P`` —
    see :func:`_grouped_ranks`; ``radix_bits``/``tile`` resolve through
    the autotuner when omitted."""
    radix_bits, tile = autotune.radix_params(impl, pid.shape[0],
                                             radix_bits, tile)
    return _grouped_ranks(pid, num_partitions, impl=impl,
                          radix_bits=radix_bits, tile=tile)
