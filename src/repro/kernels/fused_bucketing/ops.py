"""Jitted wrapper around the fused hash+histogram+rank kernel.

:func:`fused_bucket_ranks` is the op ``bucketing.group_to_slabs`` calls:
given key bit-planes and a validity mask it returns, in one fused pass,
each row's bucket id, the per-bucket histogram (trash bucket included)
and each row's stable within-bucket rank — everything the slab scatter
needs.  The tile shape is resolved through ``kernels.autotune``
(``REPRO_TILE`` override) at trace time.
"""
import functools

import jax
import jax.numpy as jnp

from .. import autotune
from .kernel import fused_bucket_ranks_tiles
from .ref import fused_bucket_ranks_ref


@functools.partial(jax.jit, static_argnames=("num_buckets", "impl", "tile"))
def _fused_bucket_ranks(bits: tuple, valid: jnp.ndarray, num_buckets: int,
                        impl: str, tile: int):
    n = valid.shape[0]
    if impl == "ref" or n < tile:
        return fused_bucket_ranks_ref(bits, valid, num_buckets)

    n_tiles = -(-n // tile)
    pad = n_tiles * tile - n
    # pad rows carry valid=0 -> the kernel routes them to the trash
    # bucket P; they sit at the tail, so real rows' cross-tile offsets
    # are unaffected — only the trash histogram column needs the pad
    # contribution subtracted.
    bt = jnp.stack([jnp.pad(b, (0, pad)) for b in bits]) \
        .reshape(len(bits), n_tiles, tile).transpose(1, 0, 2)
    vt = jnp.pad(valid.astype(jnp.int32), (0, pad)).reshape(n_tiles, tile)
    bid_t, hist_t, rank_t = fused_bucket_ranks_tiles(
        bt, vt, num_buckets, interpret=(impl == "pallas_interpret"))
    # cross-tile exclusive scan: rank of row in tile t = within-tile rank
    # + sum of its bucket's counts in earlier tiles.
    tile_offsets = jnp.cumsum(hist_t, axis=0) - hist_t    # (n_tiles, P+1)
    ranks = (rank_t + jnp.take_along_axis(
        tile_offsets, bid_t, axis=1)).reshape(-1)[:n]
    hist = jnp.sum(hist_t, axis=0).at[num_buckets].add(-pad)
    return bid_t.reshape(-1)[:n], hist, ranks


def fused_bucket_ranks(bits: tuple, valid: jnp.ndarray, num_buckets: int,
                       *, impl: str = "ref", tile: int | None = None):
    """(bid (n,), hist (P+1,), ranks (n,)) — see ``ref.py`` for the
    contract.  impl: 'ref' (pure jnp), 'pallas' (TPU), 'pallas_interpret'
    (CPU check); ``tile=None`` resolves via the autotuner."""
    if tile is None:
        tile = autotune.tuned("tile", impl, valid.shape[0])
    return _fused_bucket_ranks(tuple(bits), valid, num_buckets, impl, tile)
