from .ops import fused_bucket_ranks
from .ref import bucket_ids, fused_bucket_ranks_ref

__all__ = ["fused_bucket_ranks", "fused_bucket_ranks_ref", "bucket_ids"]
