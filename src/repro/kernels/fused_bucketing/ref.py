"""Pure-jnp oracle for the fused bucketing kernel.

One logical pass over the rows: murmur-mix the key bit-planes into a
bucket id, histogram the ids, and rank each row stably within its bucket
— the grouping pass shared by every bucketed kernel family
(``hash_join`` / ``hash_groupby`` / ``hash_semi`` and, through
``bucketing.group_to_slabs``, the set operators).  The hash chain here is
the *canonical* definition (``bucketing.bucket_ids`` re-exports it): the
kernel in ``kernel.py`` fuses exactly these ops per tile, so equal keys
land in equal buckets on every backend, bit for bit.

Invalid rows take the trash bucket ``num_buckets`` — they are counted in
``hist[num_buckets]`` and never collide with a real bucket's slots.
"""
import jax
import jax.numpy as jnp

_GOLDEN = 0x9E3779B9


def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 over uint32 (same family as core.partition)."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def bucket_ids(bits: tuple, num_buckets: int) -> jnp.ndarray:
    """Combined bucket id over key bit-planes (equal keys -> equal bucket)."""
    h = jnp.full(bits[0].shape, jnp.uint32(_GOLDEN))
    for b in bits:
        u = jax.lax.bitcast_convert_type(b, jnp.uint32)
        h = _mix32(h ^ (u + jnp.uint32(_GOLDEN) + (h << 6) + (h >> 2)))
    return (h % jnp.uint32(num_buckets)).astype(jnp.int32)


def fused_bucket_ranks_ref(bits: tuple, valid: jnp.ndarray,
                           num_buckets: int):
    """(bid (n,), hist (P+1,), ranks (n,)) for P = num_buckets.

    ``bid`` is ``num_buckets`` (trash) for invalid rows; ``hist`` covers
    the P real buckets plus the trash bucket; ``ranks`` are stable (row
    order) within each bucket including trash.
    """
    bid = jnp.where(valid, bucket_ids(bits, num_buckets), num_buckets)
    cols = jnp.arange(num_buckets + 1, dtype=bid.dtype)
    onehot = (bid[:, None] == cols[None, :]).astype(jnp.int32)
    hist = jnp.sum(onehot, axis=0)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    ranks = jnp.sum(excl * onehot, axis=1)
    return bid, hist, ranks
