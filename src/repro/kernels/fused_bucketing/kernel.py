"""Pallas TPU fused hash + histogram + rank kernel.

The single-pass grouping behind ``bucketing.group_to_slabs``: where the
unfused path ran one pass to hash rows into bucket ids and a *second*
kernel pass (``hash_partition``) to histogram/rank them, this kernel does
both in one sweep over each tile — the murmur mix-chain over the key
bit-planes stays in VREGs and feeds the one-hot occupancy matrix
directly, so bucket ids are never materialized to HBM between passes.

Tiling (same scheme as ``hash_partition/kernel.py``): the row axis is
blocked into ``(n_tiles, tile)``; each grid step loads one ``(1, K,
tile)`` slab of bit-planes plus its ``(1, tile)`` validity slab into
VMEM, mixes the K planes into a per-row bucket id, then materializes the
``(tile, P+1)`` one-hot (P real buckets + 1 trash column for invalid
rows) and reduces it two ways: per-tile histogram ``(1, P+1)`` and
within-tile ranks ``(1, tile)``.  The cross-tile exclusive scan is
composed outside in ``ops.py``, keeping the grid embarrassingly parallel
(``dimension_semantics=("parallel",)``).

VMEM budget: tile=1024, P<=512 -> one-hot is 1024*513*4 B ~ 2 MiB, well
under the ~16 MiB/core VMEM of TPU v5e.  ``tile`` is resolved through
``kernels.autotune`` (``REPRO_TILE`` override).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ..compat import TPUCompilerParams
from .ref import _GOLDEN, _mix32


def _kernel(bits_ref, valid_ref, bid_ref, hist_ref, rank_ref, *,
            num_buckets: int, num_keys: int):
    tile = valid_ref.shape[1]
    h = jnp.full((tile,), jnp.uint32(_GOLDEN))
    for k in range(num_keys):
        u = jax.lax.bitcast_convert_type(bits_ref[0, k, :], jnp.uint32)
        h = _mix32(h ^ (u + jnp.uint32(_GOLDEN) + (h << 6) + (h >> 2)))
    bid = (h % jnp.uint32(num_buckets)).astype(jnp.int32)
    bid = jnp.where(valid_ref[0, :] > 0, bid, num_buckets)
    cols = jax.lax.broadcasted_iota(jnp.int32, (tile, num_buckets + 1), 1)
    onehot = (bid[:, None] == cols).astype(jnp.int32)   # (tile, P+1)
    bid_ref[0, :] = bid
    hist_ref[0, :] = jnp.sum(onehot, axis=0)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    rank_ref[0, :] = jnp.sum(excl * onehot, axis=1)


def fused_bucket_ranks_tiles(bits_tiles: jnp.ndarray,
                             valid_tiles: jnp.ndarray, num_buckets: int,
                             *, interpret: bool = False):
    """``bits_tiles`` int32 ``(n_tiles, K, tile)``, ``valid_tiles`` int32
    ``(n_tiles, tile)`` -> (bid ``(n_tiles, tile)``, hist ``(n_tiles,
    P+1)``, ranks ``(n_tiles, tile)``)."""
    n_tiles, num_keys, tile = bits_tiles.shape
    kern = functools.partial(_kernel, num_buckets=num_buckets,
                             num_keys=num_keys)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = TPUCompilerParams(
            dimension_semantics=("parallel",))
    return pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, num_keys, tile), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, num_buckets + 1), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, tile), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, num_buckets + 1), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, tile), jnp.int32),
        ],
        interpret=interpret,
        **kwargs,
    )(bits_tiles, valid_tiles)
