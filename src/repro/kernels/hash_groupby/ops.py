"""Jitted bucketed hash-accumulate groupby plan.

:func:`hash_groupby_plan` is the op the table engine calls for
``groupby_aggregate(impl="hash")`` (and, key-only, for
``drop_duplicates(impl="hash")``): it buckets the table's rows by a
murmur-style key hash using the shared ``kernels.bucketing`` slab
machinery, then runs the bucketed accumulate (Pallas kernel on TPU,
pure-jnp ref elsewhere), which computes **sum/count/min/max for every
distinct key in one dense pass — no sort anywhere in the plan**.  Equal
keys always share a bucket, so per-bucket aggregation is exact; the
bucket slabs keep original row order, so each group's representative
slot is the key's *first occurrence* in the table (what pandas
``drop_duplicates`` keeps).

Static-shape contract (the same philosophy as the hash join): a bucket
holds at most ``bucket_capacity`` rows.  Overflowing rows are dropped and
*counted* (``dropped``) — callers size the capacity so the counter is
zero, and the conformance suite checks it trips exactly at capacity.

The plan takes **key bit-planes**, not raw key columns: the engine
extracts them once (``bucketing.BucketPlan`` / ``bucketing.key_bits`` —
floats bitcast to int32 after normalizing ``-0.0`` to ``+0.0``) and
shares them with the host-side sizing pass, so sizing and aggregation
never re-hash the same columns.  Multi-column keys are exact — the hash
only picks the bucket; group identity is decided on the full key bits.
NaN float keys group equal-by-bits (grouping on NaN keys is out of
contract, as it is for the sort backend's sort order).
"""
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..bucketing import (EXACT_SLAB_CAP, default_bucket_count,  # noqa: F401
                         group_to_slabs, key_bits)
from .kernel import bucket_accumulate_buckets
from .ref import bucket_accumulate_ref


class HashGroupbyPlan(NamedTuple):
    """Per-slot accumulate results in bucket-slab space.

    The slab arrays are indexed by (bucket, slot); ``row`` maps a slot
    back to its original table row (group representatives map to the
    key's first occurrence).  Aggregates are only meaningful at slots
    with ``rep != 0``.
    """

    rep: jnp.ndarray       # (B, C) int32: slot is a group representative
    row: jnp.ndarray       # (B, C) int32 original row per slot
    counts: jnp.ndarray    # (B, C) int32 group sizes
    sums: jnp.ndarray      # (B, V, C) f32 per-value-column group sums
    mins: jnp.ndarray      # (B, V, C) f32
    maxs: jnp.ndarray      # (B, V, C) f32
    dropped: jnp.ndarray   # () int32 rows lost to bucket overflow


@functools.partial(jax.jit, static_argnames=("num_buckets",
                                             "bucket_capacity", "impl"))
def hash_groupby_plan(key_bits_planes: tuple, valid: jnp.ndarray,
                      values: tuple = (), *, num_buckets: int,
                      bucket_capacity: int, impl: str = "ref",
                      bid: jnp.ndarray | None = None) -> HashGroupbyPlan:
    """Bucketed hash-accumulate over parallel key bit-planes / value
    columns.

    impl: 'ref' (pure jnp), 'pallas' (TPU), 'pallas_interpret' (CPU check).
    ``values`` may be empty (key-only grouping, e.g. drop_duplicates); a
    dummy zero column keeps the kernel signature static.  ``bid`` carries
    precomputed bucket ids (the eager sizing path's hash, via
    ``BucketPlan``) so the plan doesn't re-hash.
    """
    B, C = num_buckets, bucket_capacity
    bits = tuple(key_bits_planes)
    vals = tuple(v.astype(jnp.float32) for v in values) \
        or (jnp.zeros_like(valid, jnp.float32),)
    slab_bits, occ, row, val_slabs, dropped = group_to_slabs(
        bits, valid, B, C, impl, payload=vals, bid=bid)

    num_keys = len(bits)
    kb = slab_bits.reshape(num_keys, B, C).transpose(1, 0, 2)
    oc = occ.reshape(B, C)
    vs = jnp.stack(val_slabs).reshape(len(vals), B, C).transpose(1, 0, 2)
    if impl == "ref":
        rep, counts, sums, mins, maxs = bucket_accumulate_ref(kb, oc, vs)
    else:
        rep, counts, sums, mins, maxs = bucket_accumulate_buckets(
            kb, oc, vs, interpret=(impl == "pallas_interpret"))
    return HashGroupbyPlan(rep=rep, row=row.reshape(B, C), counts=counts,
                           sums=sums, mins=mins, maxs=maxs,
                           dropped=dropped)


def default_hash_groupby_sizes(capacity: int,
                               num_buckets: int | None = None):
    """(num_buckets, bucket_capacity) heuristics.

    Small tables (capacity <= ``bucketing.EXACT_SLAB_CAP``) get
    full-capacity slabs: every key distribution — including all-equal
    keys — aggregates with zero overflow, so the env-default hash backend
    is exact wherever the sort backend is.  Larger tables get ~16 rows
    per bucket on average (``bucketing.default_bucket_count``) with 4x
    headroom — an assumption of ~uniform key spread; with *concrete*
    (non-traced) keys the engine upgrades this to the distribution-proof
    two-pass ``bucketing.plan_bucket_sizes`` planner, and skewed traced
    workloads should pass explicit deeper, fewer buckets (the capacities
    are worst-case *per bucket*).  Any bucket count is sort-free: past
    ``bucketing.MAX_RADIX_BUCKETS`` the slab grouping switches from the
    single-pass one-hot ranking to the multi-pass ``kernels/radix_sort``
    rank."""
    if capacity <= EXACT_SLAB_CAP:
        return num_buckets or 8, max(8, capacity)
    if num_buckets is None:
        num_buckets = default_bucket_count(capacity)
    return num_buckets, max(8, -(-capacity // num_buckets) * 4)
