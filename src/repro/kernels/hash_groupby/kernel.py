"""Pallas TPU bucketed hash-accumulate groupby kernel.

Tiling: the grid is one step per hash bucket (the same layout as the
``hash_join`` probe kernel).  Each step loads that bucket's slab (``(K,
C)`` key bit-planes, ``(C,)`` occupancy, ``(V, C)`` float32 value columns)
into VMEM and materializes the dense ``(C, C)`` key-equality matrix in
VREGs — all static indexing, pure VPU work (broadcast-compare + masked
row reductions).  Per bucket it reduces the equality matrix four ways:

* ``rep``    ``(1, C)`` — slot is its key's first occurrence (no earlier
  equal slot: reduction over the strict lower triangle);
* ``counts`` ``(1, C)`` — group sizes;
* ``sums`` / ``mins`` / ``maxs`` ``(1, V, C)`` — masked value reductions
  per group, every aggregate in the same single pass (no sort anywhere).

Buckets are independent (``dimension_semantics=("parallel",)``); the
canonical-order output assembly (representative compaction + key ranking)
is composed outside the kernel in ``ops.py``/``local_ops`` where XLA
handles the dynamic scatters.

VMEM budget: the equality matrix dominates at ``C*C*4`` bytes — C=512
(the full-capacity exact-sizing ceiling) means 1 MiB, far under the
~16 MiB/core of TPU v5e.  ``C`` multiples of 128 (or at least 8) are
recommended for lane alignment.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ..compat import TPUCompilerParams


def _kernel(kbits_ref, occ_ref, vals_ref,
            rep_ref, counts_ref, sums_ref, mins_ref, maxs_ref,
            *, num_keys: int, num_vals: int):
    occ = occ_ref[0, :]                                    # (C,)
    eq = (occ[:, None] > 0) & (occ[None, :] > 0)           # (C, C)
    for k in range(num_keys):
        eq = eq & (kbits_ref[0, k, :][:, None]
                   == kbits_ref[0, k, :][None, :])
    m = eq.astype(jnp.int32)
    counts_ref[0, :] = jnp.sum(m, axis=1)
    cap = occ.shape[0]
    earlier = jax.lax.broadcasted_iota(jnp.int32, (cap, cap), 1) \
        < jax.lax.broadcasted_iota(jnp.int32, (cap, cap), 0)  # j < i
    rep = (occ > 0) & (jnp.sum(m * earlier.astype(jnp.int32), axis=1) == 0)
    rep_ref[0, :] = rep.astype(jnp.int32)
    for v in range(num_vals):
        x = vals_ref[0, v, :][None, :]                     # (1, C)
        sums_ref[0, v, :] = jnp.sum(jnp.where(eq, x, 0.0), axis=1)
        mins_ref[0, v, :] = jnp.min(jnp.where(eq, x, jnp.inf), axis=1)
        maxs_ref[0, v, :] = jnp.max(jnp.where(eq, x, -jnp.inf), axis=1)


def bucket_accumulate_buckets(kbits: jnp.ndarray, occ: jnp.ndarray,
                              vals: jnp.ndarray, *,
                              interpret: bool = False):
    """kbits (B, K, C) int32, occ (B, C) int32, vals (B, V, C) f32 ->
    (rep (B, C) int32, counts (B, C) int32, sums/mins/maxs (B, V, C))."""
    n_buckets, num_keys, cap = kbits.shape
    num_vals = vals.shape[1]
    kern = functools.partial(_kernel, num_keys=num_keys, num_vals=num_vals)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = TPUCompilerParams(
            dimension_semantics=("parallel",))
    val_spec = pl.BlockSpec((1, num_vals, cap), lambda i: (i, 0, 0))
    val_shape = jax.ShapeDtypeStruct((n_buckets, num_vals, cap),
                                     jnp.float32)
    return pl.pallas_call(
        kern,
        grid=(n_buckets,),
        in_specs=[
            pl.BlockSpec((1, num_keys, cap), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            val_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            val_spec, val_spec, val_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_buckets, cap), jnp.int32),
            jax.ShapeDtypeStruct((n_buckets, cap), jnp.int32),
            val_shape, val_shape, val_shape,
        ],
        interpret=interpret,
        **kwargs,
    )(kbits, occ, vals)
