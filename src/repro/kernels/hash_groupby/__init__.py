from .ops import (HashGroupbyPlan, default_hash_groupby_sizes,  # noqa: F401
                  hash_groupby_plan)
