"""Pure-jnp oracle for the bucketed hash-accumulate groupby kernel.

Rows arrive already *bucket-grouped* (ops.py does the grouping with the
shared ``kernels.bucketing`` slab machinery): for each of ``B`` buckets
there is a slab of ``C`` slots, each slot holding the row's key bit-planes
(``K`` int32 planes per key), an occupancy flag, and ``V`` float32 value
columns.  Equal keys always share a bucket, so each bucket can aggregate
its own distinct keys independently — no sort, one dense pass.

Per bucket the accumulate computes, for every slot ``i``:

* ``rep``    — ``(B, C)`` int32 1 iff slot ``i`` is *occupied* and is the
  first slot in its bucket with its key (the group representative; slot
  order is original row order, so the representative is the key's first
  occurrence in the table);
* ``counts`` — ``(B, C)`` int32 number of slots with slot ``i``'s key;
* ``sums`` / ``mins`` / ``maxs`` — ``(B, V, C)`` float32 aggregates of
  each value column over the slots sharing slot ``i``'s key.

A pair of slots shares a group iff *all* key bit-planes are equal and both
slots are occupied.  Only representative slots' outputs are consumed;
the rest are computed dense (the same broadcast-compare idiom as the
``hash_join`` probe) and masked by the caller.
"""
import jax
import jax.numpy as jnp


def bucket_accumulate_ref(kbits: jnp.ndarray, occ: jnp.ndarray,
                          vals: jnp.ndarray):
    """kbits (B, K, C) int32, occ (B, C) int32 0/1, vals (B, V, C) f32 ->
    (rep (B, C) int32, counts (B, C) int32, sums/mins/maxs (B, V, C))."""
    eq = (occ[:, :, None] > 0) & (occ[:, None, :] > 0)       # (B, C, C)
    num_keys = kbits.shape[1]
    for k in range(num_keys):
        eq = eq & (kbits[:, k, :, None] == kbits[:, k, None, :])
    m = eq.astype(jnp.int32)
    counts = jnp.sum(m, axis=2)
    cap = occ.shape[1]
    earlier = jax.lax.broadcasted_iota(jnp.int32, (cap, cap), 1) \
        < jax.lax.broadcasted_iota(jnp.int32, (cap, cap), 0)  # j < i
    rep = ((occ > 0)
           & (jnp.sum(m * earlier[None].astype(jnp.int32), axis=2) == 0))
    x = vals[:, :, None, :]                                   # (B, V, 1, C)
    e = eq[:, None, :, :]                                     # (B, 1, C, C)
    sums = jnp.sum(jnp.where(e, x, 0.0), axis=3)
    mins = jnp.min(jnp.where(e, x, jnp.inf), axis=3)
    maxs = jnp.max(jnp.where(e, x, -jnp.inf), axis=3)
    return rep.astype(jnp.int32), counts, sums, mins, maxs
