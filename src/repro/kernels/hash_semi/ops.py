"""Jitted bucketed hash semi-join (membership) plan.

:func:`hash_semi_plan` is the op the table engine calls for
``isin``/``_semi_mask``/``intersect``/``difference`` under
``impl="hash"``: it buckets both sides by a murmur-style key hash using
the shared ``kernels.bucketing`` slab machinery (build side = the right
table's key set, probe side = the left rows), runs the bucketed
membership probe (Pallas kernel on TPU, pure-jnp ref elsewhere) and
returns one boolean per original left row — **membership without
materializing a join**: no match ranks, no pair-space output, no sort
anywhere in the plan.

Static-shape contract (the same philosophy as the hash join): a bucket
holds at most ``bucket_capacity`` build rows and ``probe_capacity`` probe
rows.  Overflowing rows are dropped and *counted* (``build_dropped`` /
``probe_dropped``) — callers size the capacities so both are zero, and
the conformance suite checks the counters trip exactly at capacity.  A
probe-dropped left row's membership is unknown: it reports ``member=
False`` / ``probed=False`` and is counted, never guessed.

The plan takes **key bit-planes**, not raw key columns: the engine
extracts them once per side (``bucketing.BucketPlan`` /
``bucketing.key_bits`` — floats bitcast to int32 after normalizing
``-0.0`` to ``+0.0``) and shares them with the host-side sizing pass, so
build and probe never re-hash the same columns.  Multi-column keys are
exact — the hash only picks the bucket; membership is decided on the
full key bits.  NaN float keys compare equal-by-bits (membership of NaN
keys is out of contract, as it is for the sort-merge path's sort order).
The engine casts both sides to their *promoted* common dtype before
extracting the planes (the same rule as the sort-merge path), so
mixed-dtype probes cannot collide distinct keys.
"""
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..bucketing import group_to_slabs, key_bits  # noqa: F401
from ..hash_join import default_hash_join_sizes
from .kernel import bucket_member_buckets
from .ref import bucket_member_ref

# build slab = right key set, probe slab = left rows: the sizing problem
# is identical to the hash join's build/probe slabs, so the heuristics
# (full-capacity slabs up to EXACT_SLAB_CAP, ~16 rows/bucket with 4x
# headroom above) are shared verbatim.
default_hash_semi_sizes = default_hash_join_sizes


class HashSemiPlan(NamedTuple):
    """Membership results mapped back to original left-row ids."""

    member: jnp.ndarray          # (Lcap,) bool: key present in build side
    probed: jnp.ndarray          # (Lcap,) bool: left row made it into a slab
    build_dropped: jnp.ndarray   # () int32 right rows lost to slab overflow
    probe_dropped: jnp.ndarray   # () int32 left rows lost to slab overflow


@functools.partial(jax.jit, static_argnames=("num_buckets",
                                             "bucket_capacity",
                                             "probe_capacity", "impl"))
def hash_semi_plan(left_bits: tuple, left_valid: jnp.ndarray,
                   right_bits: tuple, right_valid: jnp.ndarray, *,
                   num_buckets: int, bucket_capacity: int,
                   probe_capacity: int, impl: str = "ref",
                   left_bid: jnp.ndarray | None = None,
                   right_bid: jnp.ndarray | None = None) -> HashSemiPlan:
    """Bucketed build (right key set) + membership probe (left) over
    parallel key bit-planes.

    impl: 'ref' (pure jnp), 'pallas' (TPU), 'pallas_interpret' (CPU check).
    ``left_bid`` / ``right_bid`` carry precomputed bucket ids (the eager
    sizing path's hash, via ``BucketPlan``) so the plan doesn't re-hash.
    """
    B, C, Lc = num_buckets, bucket_capacity, probe_capacity
    lbits, rbits = tuple(left_bits), tuple(right_bits)
    lcap = left_valid.shape[0]

    bslab, bocc, _, _, build_dropped = group_to_slabs(
        rbits, right_valid, B, C, impl, bid=right_bid)
    pslab, pocc, prow, _, probe_dropped = group_to_slabs(
        lbits, left_valid, B, Lc, impl, bid=left_bid)

    num_keys = len(lbits)
    pb = pslab.reshape(num_keys, B, Lc).transpose(1, 0, 2)
    bb = bslab.reshape(num_keys, B, C).transpose(1, 0, 2)
    po = pocc.reshape(B, Lc)
    bo = bocc.reshape(B, C)
    if impl == "ref":
        member_g = bucket_member_ref(pb, po, bb, bo)
    else:
        member_g = bucket_member_buckets(
            pb, po, bb, bo, interpret=(impl == "pallas_interpret"))

    # member + probed back to original left-row order in ONE stacked
    # scatter (trash slot lcap for empties)
    idx = jnp.where(pocc > 0, prow, lcap)
    packed = (jnp.zeros((2, lcap + 1), jnp.int32)
              .at[:, idx].set(jnp.stack([
                  (member_g.reshape(-1) > 0).astype(jnp.int32),
                  (pocc > 0).astype(jnp.int32)]))[:, :lcap])
    return HashSemiPlan(member=packed[0] > 0, probed=packed[1] > 0,
                        build_dropped=build_dropped,
                        probe_dropped=probe_dropped)
