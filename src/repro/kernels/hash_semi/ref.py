"""Pure-jnp oracle for the bucketed hash semi-join membership kernel.

Both sides arrive already *bucket-grouped* (ops.py does the grouping with
the shared ``kernels.bucketing`` slab machinery): for each of ``B``
buckets there is a probe slab of ``Lc`` slots and a build slab of ``C``
slots, each slot holding the row's key bit-planes (``K`` int32 planes per
key) plus an occupancy flag.  The membership probe computes, per bucket:

* ``member`` — ``(B, Lc)`` int32 1 iff the probe slot is occupied and
  *any* occupied build slot carries the same key.

This is the hash join probe with the ``(Lc, C)`` match matrix reduced to
a single boolean per probe row — no match ranks, no pair-space output, so
a semi-join/membership filter never materializes a join.  A pair matches
iff *all* key bit-planes are equal and both slots are occupied; equal
keys always share a bucket (``bucketing.bucket_ids``), so the per-bucket
reduction is exact.
"""
import jax.numpy as jnp


def bucket_member_ref(pbits: jnp.ndarray, pocc: jnp.ndarray,
                      bbits: jnp.ndarray, bocc: jnp.ndarray):
    """pbits (B, K, Lc) int32, pocc (B, Lc) int32 0/1, bbits (B, K, C),
    bocc (B, C) -> member (B, Lc) int32 0/1."""
    match = (pocc[:, :, None] > 0) & (bocc[:, None, :] > 0)
    num_keys = pbits.shape[1]
    for k in range(num_keys):
        match = match & (pbits[:, k, :, None] == bbits[:, k, None, :])
    return jnp.any(match, axis=2).astype(jnp.int32)
