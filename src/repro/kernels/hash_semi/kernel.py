"""Pallas TPU bucketed hash semi-join membership kernel.

Tiling: the grid is one step per hash bucket (the same layout as the
``hash_join`` probe kernel).  Each step loads that bucket's probe slab
(``(K, Lc)`` key bit-planes + ``(Lc,)`` occupancy) and build slab
(``(K, C)`` + ``(C,)``) into VMEM and materializes the dense ``(Lc, C)``
equality matrix in VREGs — all static indexing, pure VPU work
(broadcast-compare + one row reduction).  Per bucket it reduces the match
matrix a single way:

* ``member`` ``(1, Lc)`` — any build slot matches the probe slot.

That is the whole output: membership filtering needs no match ranks and
no pair-space scatter, so the semi-join's VMEM working set is the same
``Lc*C`` compare matrix as the join probe but its HBM traffic is
``O(Lc)`` instead of ``O(Lc*C)``.

Buckets are independent (``dimension_semantics=("parallel",)``); mapping
members back to original row order is composed outside the kernel in
``ops.py`` where XLA handles the dynamic scatter.

VMEM budget: the match matrix dominates at ``Lc*C*4`` bytes — Lc=C=512
(the full-capacity exact-sizing ceiling) means 1 MiB, far under the
~16 MiB/core of TPU v5e.  ``Lc``/``C`` multiples of 128 (or at least 8)
are recommended for lane alignment.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ..compat import TPUCompilerParams


def _kernel(pbits_ref, pocc_ref, bbits_ref, bocc_ref, member_ref,
            *, num_keys: int):
    pocc = pocc_ref[0, :]                                  # (Lc,)
    bocc = bocc_ref[0, :]                                  # (C,)
    match = (pocc[:, None] > 0) & (bocc[None, :] > 0)      # (Lc, C)
    for k in range(num_keys):
        match = match & (pbits_ref[0, k, :][:, None]
                         == bbits_ref[0, k, :][None, :])
    member_ref[0, :] = (jnp.sum(match.astype(jnp.int32), axis=1)
                        > 0).astype(jnp.int32)


def bucket_member_buckets(pbits: jnp.ndarray, pocc: jnp.ndarray,
                          bbits: jnp.ndarray, bocc: jnp.ndarray,
                          *, interpret: bool = False):
    """pbits (B, K, Lc) int32, pocc (B, Lc) int32, bbits (B, K, C),
    bocc (B, C) -> member (B, Lc) int32 0/1."""
    n_buckets, num_keys, probe_cap = pbits.shape
    chain_cap = bbits.shape[2]
    kern = functools.partial(_kernel, num_keys=num_keys)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = TPUCompilerParams(
            dimension_semantics=("parallel",))
    return pl.pallas_call(
        kern,
        grid=(n_buckets,),
        in_specs=[
            pl.BlockSpec((1, num_keys, probe_cap), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, probe_cap), lambda i: (i, 0)),
            pl.BlockSpec((1, num_keys, chain_cap), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, chain_cap), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, probe_cap), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_buckets, probe_cap), jnp.int32),
        interpret=interpret,
        **kwargs,
    )(pbits, pocc, bbits, bocc)
