from .ops import (HashSemiPlan, default_hash_semi_sizes,  # noqa: F401
                  hash_semi_plan)
