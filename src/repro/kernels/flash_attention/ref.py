"""Pure-jnp oracle for fused attention (GQA + causal)."""
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  scale: float | None = None):
    """q: (B, Hq, Sq, D); k,v: (B, Hkv, Skv, D); Hq % Hkv == 0.

    fp32 math throughout — the tolerance anchor for the Pallas kernel.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if causal:
        qi = jnp.arange(Sq)[:, None] + (Skv - Sq)   # right-aligned
        kj = jnp.arange(Skv)[None, :]
        s = jnp.where(kj > qi, -jnp.inf, s)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
