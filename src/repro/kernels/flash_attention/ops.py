"""Jitted wrapper for the flash attention kernel with backend dispatch."""
import functools

import jax

from .kernel import flash_attention_fwd
from .ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "impl", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, impl: str = "ref",
                    bq: int = 512, bk: int = 512):
    """Fused attention: impl in {'ref', 'pallas', 'pallas_interpret'}."""
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal)
    return flash_attention_fwd(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=(impl == "pallas_interpret"))
