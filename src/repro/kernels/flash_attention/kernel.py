"""Pallas TPU flash attention (fwd) — blockwise online softmax.

Tiling (FlashAttention re-thought for VMEM/MXU, not a CUDA port):
* grid ``(B, Hq, Sq/bq, Skv/bk)``; the KV dimension is the innermost,
  sequential ("arbitrary") grid axis — running max ``m``, normalizer ``l``
  and the output accumulator live in VMEM scratch across KV steps.
* block shapes ``(bq, D)`` / ``(bk, D)`` with ``D`` padded to 128 by the
  caller — MXU-aligned matmul dims; default bq=bk=512 keeps the working
  set (q, k, v, s, acc ≈ bq*D + 2*bk*D + bq*bk + bq*D floats ≈ 2.5 MiB
  at D=128) comfortably inside the ~16 MiB v5e VMEM.
* GQA is expressed in the ``index_map`` — query head ``h`` reads KV head
  ``h // group`` — no repeated KV materialization in HBM.
* causal masking uses global row/col ids; fully-masked KV blocks are
  skipped with ``pl.when`` (upper-triangle blocks cost ~0).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ..compat import TPUCompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, skv: int,
            sq: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[:] = jnp.full_like(m_ref, NEG_INF)
        l_ref[:] = jnp.zeros_like(l_ref)
        acc_ref[:] = jnp.zeros_like(acc_ref)

    # global row/col coordinates (right-aligned causal for Sq < Skv)
    row = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (skv - sq)
    col = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    if causal:  # skip fully-masked upper-triangle KV blocks
        live = kj * bk <= qi * bq + (bq - 1) + (skv - sq)
    else:
        live = jnp.bool_(True)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            s = jnp.where(col > row, NEG_INF, s)
        m_prev = m_ref[:]                                    # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                               # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[:] = l_ref[:] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[:] = acc_ref[:] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[:] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:], 1e-30)
        o_ref[0, 0] = (acc_ref[:] / l).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        scale: float | None = None, bq: int = 512,
                        bk: int = 512, interpret: bool = False):
    """q: (B, Hq, Sq, D); k,v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    scale = scale if scale is not None else D ** -0.5
    grid = (B, Hq, Sq // bq, Skv // bk)

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             bq=bq, bk=bk, skv=Skv, sq=Sq)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(q, k, v)
