"""UNOMT drug-response regression network (paper §4.2, Figures 6–7).

Dense input layer -> stacked residual "response blocks" (two dense layers
+ dropout + ReLU with skip) -> dense tail -> single regression output.
Block/tail counts are hyper-parameters, as in the paper's config file.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class UnomtNetConfig:
    n_features: int = 17
    d_hidden: int = 1024
    n_res_blocks: int = 3
    n_dense_tail: int = 2
    dropout: float = 0.1


def init(key, cfg: UnomtNetConfig):
    ks = jax.random.split(key, 3 + 2 * cfg.n_res_blocks + cfg.n_dense_tail)
    def lin(k, i, o):
        return {"w": jax.random.normal(k, (i, o), F32)
                * (2.0 / i) ** 0.5, "b": jnp.zeros((o,), F32)}
    p = {"input": lin(ks[0], cfg.n_features, cfg.d_hidden), "blocks": [],
         "tail": [], "out": lin(ks[1], cfg.d_hidden, 1)}
    for b in range(cfg.n_res_blocks):
        p["blocks"].append({
            "fc1": lin(ks[2 + 2 * b], cfg.d_hidden, cfg.d_hidden),
            "fc2": lin(ks[3 + 2 * b], cfg.d_hidden, cfg.d_hidden),
        })
    off = 2 + 2 * cfg.n_res_blocks
    for t in range(cfg.n_dense_tail):
        p["tail"].append(lin(ks[off + t], cfg.d_hidden, cfg.d_hidden))
    return p


def _lin(p, x):
    return x @ p["w"] + p["b"]


def apply(p, cfg: UnomtNetConfig, x, *, train: bool = False, key=None):
    h = jax.nn.relu(_lin(p["input"], x))
    for blk in p["blocks"]:
        r = jax.nn.relu(_lin(blk["fc1"], h))
        r = _lin(blk["fc2"], r)
        if train and key is not None and cfg.dropout > 0:
            key, sub = jax.random.split(key)
            keep = jax.random.bernoulli(sub, 1 - cfg.dropout, r.shape)
            r = jnp.where(keep, r / (1 - cfg.dropout), 0.0)
        h = jax.nn.relu(h + r)               # response block + skip
    for t in p["tail"]:
        h = jax.nn.relu(_lin(t, h))
    return _lin(p["out"], h)[:, 0]


def mse_loss(p, cfg: UnomtNetConfig, batch, *, train: bool = False,
             key=None):
    pred = apply(p, cfg, batch["x"], train=train, key=key)
    mask = batch.get("mask")
    err = (pred - batch["y"]) ** 2
    if mask is not None:
        m = mask.astype(F32)
        loss = jnp.sum(err * m) / jnp.maximum(jnp.sum(m), 1.0)
    else:
        loss = jnp.mean(err)
    return loss, {"mse": loss}
