"""Mixture-of-Experts with HPTMT shuffle dispatch.

The paper's central operator — the table Shuffle (hash partition +
``all_to_all``) — *is* MoE token dispatch: rows = tokens, partition key =
routed expert, destination shard = expert owner.  ``moe_shuffle`` composes
the same ``radix_histogram_ranks`` plan used by ``core.dist_ops.shuffle``
with an ``all_to_all`` over the model axis (expert parallelism), exactly
the paper's "distributed operator = communication + local operator"
recipe (DESIGN.md §2).

Three paths:
* ``moe_dense``   — compute-all-experts fallback (smoke tests, 1 device,
  or expert counts indivisible by the model axis, e.g. granite's 40);
* ``moe_shuffle`` — shard_map EP dispatch for train/prefill (seq sharded
  over the model axis inside the block).  Only the token payload crosses
  the wire (bf16); routing metadata stays local because the tiled
  all_to_all is slot-symmetric — the return trip lands each row back in
  the slot it was sent from;
* ``moe_decode``  — replicated-token decode: each rank serves its local
  experts and combines with ``psum`` (cheaper than all_to_all at step
  sizes of a few hundred tokens).

Uneven expert counts are parameter-padded to a multiple of 16
(``cfg.n_experts`` stays the routing width; pads receive no tokens).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.context import shard_map
from ..kernels.hash_partition import radix_histogram_ranks
from . import layers as Ly

F32 = jnp.float32


def n_experts_padded(cfg) -> int:
    E = cfg.n_experts
    return math.ceil(E / 16) * 16 if E >= 16 else E


def moe_init(key, cfg):
    d = cfg.d_model
    E = n_experts_padded(cfg)
    f = cfg.d_expert_ff or cfg.d_ff
    ks = jax.random.split(key, 4)
    std = Ly.INIT_STD
    return {
        "router": jax.random.normal(ks[0], (d, cfg.n_experts), F32) * std,
        "e_gate": jax.random.normal(ks[1], (E, d, f), F32) * std,
        "e_up": jax.random.normal(ks[2], (E, d, f), F32) * std,
        "e_down": jax.random.normal(ks[3], (E, f, d), F32)
        * (std / math.sqrt(2 * cfg.n_layers)),
    }


def _route(router, x2d, top_k: int):
    """x2d (T, d) -> (weights (T,k) f32, ids (T,k) i32, aux-loss scalar)."""
    logits = x2d.astype(F32) @ router.astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, ids = jax.lax.top_k(probs, top_k)
    w = vals / jnp.maximum(jnp.sum(vals, axis=-1, keepdims=True), 1e-9)
    E = router.shape[1]
    frac = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=F32), axis=0)
    pmean = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * pmean)
    return w, ids.astype(jnp.int32), aux


def _expert_ffn(eg, eu, ed, xb):
    """xb (E_loc, C, d) -> (E_loc, C, d); bf16 GEMMs."""
    bf = jnp.bfloat16
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb.astype(bf),
                               eg.astype(bf)))
    u = jnp.einsum("ecd,edf->ecf", xb.astype(bf), eu.astype(bf))
    return jnp.einsum("ecf,efd->ecd", g * u, ed.astype(bf))


# --------------------------------------------------------------------------
# dense fallback
# --------------------------------------------------------------------------


def moe_dense(p, cfg, x):
    B, S, d = x.shape
    E = cfg.n_experts
    x2 = x.reshape(B * S, d)
    w, ids, aux = _route(p["router"], x2, cfg.top_k)
    gates = jnp.sum(jax.nn.one_hot(ids, E, dtype=F32) * w[..., None],
                    axis=1)                                   # (T, E)
    bf = jnp.bfloat16
    eg, eu, ed = (p["e_gate"][:E], p["e_up"][:E], p["e_down"][:E])
    h = jnp.einsum("td,edf->tef", x2.astype(bf), eg.astype(bf))
    u = jnp.einsum("td,edf->tef", x2.astype(bf), eu.astype(bf))
    o = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, ed.astype(bf))
    y = jnp.einsum("ted,te->td", o.astype(F32), gates)
    return y.reshape(B, S, d).astype(x.dtype), aux


# --------------------------------------------------------------------------
# shuffle-dispatch EP (train / prefill) — the paper's operator
# --------------------------------------------------------------------------


def _batch_axes_for(policy, B: int):
    """Batch axes actually usable for B (drop them if indivisible)."""
    world_b = 1
    for a in policy.batch_axes:
        world_b *= policy.mesh.shape[a]
    return policy.batch_axes if B % world_b == 0 else ()


def moe_shuffle(p, cfg, x, policy, capacity_factor: float = 1.25):
    mesh = policy.mesh
    maxis = policy.model_axis
    world_m = mesh.shape[maxis]
    E = cfg.n_experts
    E_pad = n_experts_padded(cfg)
    if world_m == 1 or E_pad % world_m != 0 \
            or x.shape[1] % world_m != 0:
        return moe_dense(p, cfg, x)
    baxes = _batch_axes_for(policy, x.shape[0])
    batch_spec = P(baxes, maxis, None)
    aux_spec = P(baxes, maxis)

    def local(x_loc, router, eg, eu, ed):
        b, s, d = x_loc.shape
        T = b * s
        k = cfg.top_k
        E_loc = E_pad // world_m
        C_send = max(1, math.ceil(T * k / E * capacity_factor))
        slots = E_loc * C_send
        x2 = x_loc.reshape(T, d)
        w, ids, aux = _route(router, x2, k)

        # ---- shuffle plan: stable rank of each routed row in its expert
        eid = ids.reshape(-1)                                 # (T*k,)
        src = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
        wf = w.reshape(-1).astype(F32)
        _, ranks = radix_histogram_ranks(eid, E)
        owner = eid // E_loc
        le = eid % E_loc
        ok = ranks < C_send
        flat = jnp.where(ok, owner * slots + le * C_send + ranks,
                         world_m * slots)

        payload = jnp.zeros((world_m * slots + 1, d), jnp.bfloat16) \
            .at[flat].set(x2.astype(jnp.bfloat16)[src])[:-1] \
            .reshape(world_m, slots, d)

        a2a = partial(jax.lax.all_to_all, axis_name=maxis, split_axis=0,
                      concat_axis=0, tiled=True)
        r_pay = a2a(payload)                       # (world_m, slots, d)
        xb = r_pay.reshape(world_m, E_loc, C_send, d) \
            .transpose(1, 0, 2, 3).reshape(E_loc, world_m * C_send, d)
        h = _expert_ffn(eg, eu, ed, xb)
        h = h.reshape(E_loc, world_m, C_send, d).transpose(1, 0, 2, 3) \
            .reshape(world_m, slots, d)
        y_rows = a2a(h).reshape(world_m * slots, d)  # back in my layout

        g = y_rows[jnp.clip(flat, 0, world_m * slots - 1)].astype(F32)
        contrib = g * (wf * ok)[:, None]
        y = jnp.zeros((T, d), F32).at[src].add(contrib)
        return (y.reshape(b, s, d).astype(x_loc.dtype),
                aux[None, None],
                jnp.sum(~ok, dtype=jnp.int32)[None, None])

    # cast to bf16 BEFORE the boundary: the fsdp_tp data-axis gather of
    # expert weights then moves half the bytes (§Perf iter 2c);
    # numerics-identical (the expert GEMMs cast at use anyway)
    cast = (lambda w: w.astype(jnp.bfloat16)) \
        if cfg.train.bf16_weight_cast else (lambda w: w)
    y, aux, _dropped = shard_map(
        local, mesh=mesh,
        in_specs=(batch_spec, P(), P(maxis, None, None),
                  P(maxis, None, None), P(maxis, None, None)),
        out_specs=(batch_spec, aux_spec, aux_spec),
    )(x, p["router"], cast(p["e_gate"]), cast(p["e_up"]),
      cast(p["e_down"]))
    return y, jnp.mean(aux)


# --------------------------------------------------------------------------
# decode path: replicated tokens, local experts, psum combine
# --------------------------------------------------------------------------


def moe_decode(p, cfg, x, policy, capacity_factor: float = 4.0):
    mesh = policy.mesh
    maxis = policy.model_axis
    world_m = mesh.shape[maxis]
    E = cfg.n_experts
    E_pad = n_experts_padded(cfg)
    if world_m == 1 or E_pad % world_m != 0:
        return moe_dense(p, cfg, x)
    baxes = _batch_axes_for(policy, x.shape[0])
    batch_spec = P(baxes, None, None)
    aux_spec = P(baxes)

    def local(x_loc, router, eg, eu, ed):
        b, s, d = x_loc.shape
        T = b * s
        k = cfg.top_k
        E_loc = E_pad // world_m
        C = max(8, math.ceil(T * k / E * capacity_factor))
        x2 = x_loc.reshape(T, d)
        w, ids, aux = _route(router, x2, k)
        rank = jax.lax.axis_index(maxis)
        eid = ids.reshape(-1)
        src = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
        wf = w.reshape(-1).astype(F32)
        le = eid - rank * E_loc
        mine = (le >= 0) & (le < E_loc)
        le_or_trash = jnp.where(mine, le, E_loc)
        _, ranks = radix_histogram_ranks(le_or_trash, E_loc + 1)
        ok = mine & (ranks < C)
        flat = jnp.where(ok, le_or_trash * C + ranks, E_loc * C)
        xb = jnp.zeros((E_loc * C + 1, d), jnp.bfloat16) \
            .at[flat].set(x2.astype(jnp.bfloat16)[src])[:-1] \
            .reshape(E_loc, C, d)
        h = _expert_ffn(eg, eu, ed, xb).reshape(E_loc * C, d).astype(F32)
        g = h[jnp.clip(flat, 0, E_loc * C - 1)]
        contrib = g * (wf * ok)[:, None]
        part = jnp.zeros((T, d), F32).at[src].add(contrib)
        y = jax.lax.psum(part, maxis)
        return y.reshape(b, s, d).astype(x_loc.dtype), aux[None]

    y, aux = shard_map(
        local, mesh=mesh,
        in_specs=(batch_spec, P(), P(maxis, None, None),
                  P(maxis, None, None), P(maxis, None, None)),
        out_specs=(batch_spec, aux_spec),
    )(x, p["router"], p["e_gate"], p["e_up"], p["e_down"])
    return y, jnp.mean(aux)


def moe_apply(p, cfg, x, policy=None, *, decode: bool = False,
              capacity_factor: float = 1.25):
    if policy is None or policy.mesh is None:
        return moe_dense(p, cfg, x)
    if decode or x.shape[1] < policy.mesh.shape[policy.model_axis]:
        return moe_decode(p, cfg, x, policy)
    return moe_shuffle(p, cfg, x, policy, capacity_factor)
