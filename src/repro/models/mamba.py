"""Mamba-1 block (falcon-mamba, jamba mamba layers).

Three scan paths:
* ``pallas``  — the fused ``kernels/mamba_scan`` TPU kernel;
* ``xla``     — chunked ``lax.scan`` (outer scan over time chunks, inner
  scan over steps, chunk body ``jax.checkpoint``-ed) so training backward
  materializes per-step ``(B,E,N)`` residuals for *one chunk at a time*
  instead of the whole sequence — the XLA analogue of the fused kernel's
  recompute;
* ``step``    — O(1) single-token decode with (conv, ssm) state.

Sharding: everything is elementwise in E (= d_inner), which is sharded
over the model axis; the two projections contract over d/E and reduce via
GSPMD as usual.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from ..kernels.mamba_scan import selective_scan as _scan_kernel
from . import layers as Ly

F32 = jnp.float32


def dt_rank(cfg) -> int:
    return cfg.dt_rank or max(1, math.ceil(cfg.d_model / 16))


def mamba_init(key, cfg):
    d, E, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    R = dt_rank(cfg)
    ks = jax.random.split(key, 6)
    std = Ly.INIT_STD
    A = jnp.tile(jnp.arange(1, N + 1, dtype=F32)[None, :], (E, 1))
    return {
        "in_proj": Ly.dense_init(ks[0], d, 2 * E),
        "conv_w": jax.random.normal(ks[1], (K, E), F32) * std,
        "conv_b": jnp.zeros((E,), F32),
        "x_proj": Ly.dense_init(ks[2], E, R + 2 * N),
        "dt_proj": {
            "w": jax.random.normal(ks[3], (R, E), F32) * (R ** -0.5),
            "b": jnp.log(jnp.expm1(jnp.full((E,), 0.01, F32))),
        },
        "A_log": jnp.log(A),
        "D": jnp.ones((E,), F32),
        "out_proj": Ly.dense_init(
            ks[4], E, d, std=std / math.sqrt(2 * cfg.n_layers)),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv via K shifted adds. x (B,S,E), w (K,E)."""
    K = w.shape[0]
    B, S, E = x.shape
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = jnp.zeros_like(x, dtype=F32)
    for k in range(K):
        y = y + xp[:, k:k + S].astype(F32) * w[k].astype(F32)
    return y + b.astype(F32)


def _ssm_inputs(p, cfg, xc):
    """xc (B,S,E) fp32 -> (delta (B,S,E), A (E,N), Bm, Cm (B,S,N))."""
    N = cfg.ssm_state
    R = dt_rank(cfg)
    proj = xc.astype(jnp.bfloat16) @ p["x_proj"]["w"].astype(jnp.bfloat16)
    proj = proj.astype(F32)
    dt_low, Bm, Cm = proj[..., :R], proj[..., R:R + N], proj[..., R + N:]
    delta = jax.nn.softplus(
        dt_low @ p["dt_proj"]["w"].astype(F32) + p["dt_proj"]["b"])
    A = -jnp.exp(p["A_log"].astype(F32))
    return delta, A, Bm, Cm


def _scan_chunked_xla(x, delta, A, Bm, Cm, D, h0, chunk: int = 128):
    """Chunked selective scan; returns (y (B,S,E), h_final (B,E,N))."""
    B, S, E = x.shape
    N = A.shape[1]
    c = min(chunk, S)
    assert S % c == 0
    nc = S // c

    def split(v):
        return jnp.moveaxis(v.reshape(B, nc, c, -1), 1, 0)

    xs = (split(x), split(delta), split(Bm), split(Cm))

    @jax.checkpoint
    def chunk_body(h, inp):
        xc, dc, bc, cc = inp                      # (B, c, *)

        def step(hh, t_inp):
            xt, dt_, bt, ct = t_inp               # (B,E),(B,E),(B,N),(B,N)
            dA = jnp.exp(dt_[..., None] * A[None])
            hh = dA * hh + (dt_ * xt)[..., None] * bt[:, None, :]
            y = jnp.einsum("ben,bn->be", hh, ct)
            return hh, y

        h, ys = jax.lax.scan(
            step, h, (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dc, 1, 0),
                      jnp.moveaxis(bc, 1, 0), jnp.moveaxis(cc, 1, 0)))
        return h, jnp.moveaxis(ys, 0, 1)          # (B, c, E)

    hT, ys = jax.lax.scan(chunk_body, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, E)
    return y + x * D[None, None], hT


def mamba_apply(p, cfg, x, *, impl: str = "xla", scan_chunk: int = 128,
                return_state: bool = False, policy=None):
    """Full-sequence mamba block.  x (B,S,d) -> (y, state | None).

    With ``policy`` the channel dim E (= d_inner) is explicitly sharded
    over the model axis (everything SSM-internal is elementwise in E).
    Without the constraints GSPMD fails to propagate through the chunked
    time scan and replicates in_proj/out_proj and their grads —
    EXPERIMENTS.md §Perf iter 4."""
    B, S, d = x.shape
    E, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv

    def se(v):                          # shard (..., E-like) over model
        if policy is None or policy.mesh is None \
                or not cfg.train.ssm_shard_opt:
            return v
        from jax.sharding import PartitionSpec as P
        return policy.sc(v, P(policy.batch_axes, None, policy.model_axis))

    xz = se(Ly.dense(p["in_proj"], x))                   # (B,S,2E)
    x_in, z = xz[..., :E], xz[..., E:]
    xc = se(jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"])))
    delta, A, Bm, Cm = _ssm_inputs(p, cfg, xc)
    delta = se(delta)
    if impl in ("pallas", "pallas_interpret") and not return_state:
        y = _scan_kernel(xc.astype(F32), delta, A, Bm, Cm, p["D"],
                         impl=impl)
        hT = None
    else:
        h0 = jnp.zeros((B, E, N), F32)
        if policy is not None and policy.mesh is not None \
                and cfg.train.ssm_shard_opt:
            from jax.sharding import PartitionSpec as P
            h0 = policy.sc(h0, P(policy.batch_axes, policy.model_axis,
                                 None))
        y, hT = _scan_chunked_xla(xc.astype(F32), delta, A, Bm, Cm,
                                  p["D"].astype(F32), h0, scan_chunk)
    y = se(y) * jax.nn.silu(z.astype(F32))
    out = Ly.dense(p["out_proj"], y.astype(x.dtype))
    if return_state:
        if S >= K - 1:
            conv_state = x_in.astype(F32)[:, S - (K - 1):]
        else:
            conv_state = jnp.pad(x_in.astype(F32),
                                 ((0, 0), (K - 1 - S, 0), (0, 0)))
        return out, {"conv": conv_state, "ssm": hT}
    return out, None


def mamba_step(p, cfg, x, state):
    """Single-token decode.  x (B,1,d); state {"conv" (B,K-1,E) fp32,
    "ssm" (B,E,N) fp32} -> (y (B,1,d), new state)."""
    B = x.shape[0]
    E, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    xz = Ly.dense(p["in_proj"], x)                       # (B,1,2E)
    x_in, z = xz[..., :E], xz[..., E:]
    window = jnp.concatenate(
        [state["conv"], x_in.astype(F32)], axis=1)       # (B,K,E)
    xc = jnp.einsum("bke,ke->be", window, p["conv_w"].astype(F32)) \
        + p["conv_b"].astype(F32)
    xc = jax.nn.silu(xc)[:, None, :]                     # (B,1,E)
    delta, A, Bm, Cm = _ssm_inputs(p, cfg, xc)
    dA = jnp.exp(delta[:, 0, :, None] * A[None])         # (B,E,N)
    h = dA * state["ssm"] + (delta[:, 0] * xc[:, 0])[..., None] \
        * Bm[:, 0][:, None, :]
    y = jnp.einsum("ben,bn->be", h, Cm[:, 0]) \
        + xc[:, 0] * p["D"].astype(F32)[None]
    y = (y * jax.nn.silu(z[:, 0].astype(F32)))[:, None, :]
    out = Ly.dense(p["out_proj"], y.astype(x.dtype))
    new_state = {"conv": window[:, 1:], "ssm": h}
    return out, new_state
