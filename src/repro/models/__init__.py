from . import attention, layers, mamba, model, moe, sharding, transformer  # noqa: F401
from .sharding import Policy, make_policy  # noqa: F401
