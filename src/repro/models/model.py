"""Model assembly: init, forward, loss, train_step, prefill, serve_step.

All ten assigned architectures are built from the same pieces; the config
decides layer kinds (transformer.layer_kind) and the frontend stubs
([vlm]/[audio] per the assignment: precomputed patch/frame embeddings are
*inputs*, not modeled)."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..optim import adamw
from . import layers as Ly
from . import mamba as Mb
from . import transformer as Tf
from .sharding import Policy
from .transformer import StackOpts

F32 = jnp.float32


def opts_from_cfg(cfg, *, decode_len: int = 0,
                  attn_impl: str = "xla") -> StackOpts:
    t = cfg.train
    return StackOpts(attn_impl=attn_impl, q_chunk=t.attn_q_chunk,
                     k_chunk=t.attn_k_chunk, remat=t.remat,
                     moe_capacity=t.moe_capacity_factor,
                     decode_len=decode_len)


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------


def init_params(key, cfg):
    ks = jax.random.split(key, 5)
    V = cfg.padded_vocab()
    params: dict[str, Any] = {
        "embed": Ly.embed_init(ks[0], V, cfg.d_model),
        "layers": Tf.stack_init(ks[1], cfg),
        "final_norm": Ly.rms_norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = Ly.dense_init(ks[2], cfg.d_model, V)
    if cfg.is_encdec:
        params["encoder"] = {
            "layers": Tf.stack_init(ks[3], cfg, encoder=True),
            "norm": Ly.rms_norm_init(cfg.d_model),
        }
    return params


def _positions(B, S, offset=0):
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S)) \
        + offset


def _encode(params, cfg, frames, policy, opts):
    """Audio/enc-dec encoder over stub frame embeddings (B,Senc,d)."""
    x = frames.astype(jnp.bfloat16)
    pos = _positions(x.shape[0], x.shape[1])
    x, _aux, _ = Tf.stack_apply(params["encoder"]["layers"], cfg, x, pos,
                                policy, opts, causal=False, encoder=True)
    return Ly.rms_norm(params["encoder"]["norm"], x, cfg.norm_eps)


def backbone(params, cfg, batch, policy, opts, *, want_cache=False):
    """Embed -> stack -> final norm.  Returns (x, aux, caches, n_prefix).

    n_prefix = frontend tokens prepended (vlm) — loss/labels skip them."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = Ly.embed_lookup(params["embed"], tokens)
    n_prefix = 0
    if cfg.frontend == "vision":
        patches = batch["patch_embeds"].astype(x.dtype)   # (B, P, d)
        x = jnp.concatenate([patches, x], axis=1)
        n_prefix = patches.shape[1]
    if policy is not None:
        x = policy.shard_activations(x)
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(params, cfg, batch["frames"], policy, opts)
    pos = _positions(B, x.shape[1])
    x, aux, caches = Tf.stack_apply(params["layers"], cfg, x, pos, policy,
                                    opts, causal=True, enc_out=enc_out,
                                    want_cache=want_cache)
    x = Ly.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, aux, caches, n_prefix


# --------------------------------------------------------------------------
# loss (seq-chunked cross entropy: caps live logits at (B, S/chunks, V))
# --------------------------------------------------------------------------


def _head_weight(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"]["embed"].T
    return params["lm_head"]["w"]


def ce_loss(params, cfg, x, labels, chunks: int = 1):
    """x (B,S,d) fp/bf16, labels (B,S) int32 (-1 = masked)."""
    B, S, d = x.shape
    w = _head_weight(params, cfg).astype(jnp.bfloat16)
    chunks = max(1, min(chunks, S))
    while S % chunks != 0:
        chunks -= 1
    c = S // chunks

    @jax.checkpoint
    def chunk_loss(_, inp):
        xc, yc = inp                                    # (B,c,d), (B,c)
        logits = jax.lax.dot_general(
            xc.astype(jnp.bfloat16), w, (((2,), (0,)), ((), ())),
            preferred_element_type=F32)                 # (B,c,V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(yc, 0)[..., None], axis=-1)[..., 0]
        mask = (yc >= 0).astype(F32)
        return None, (jnp.sum((lse - gold) * mask), jnp.sum(mask))

    xs = (jnp.moveaxis(x.reshape(B, chunks, c, d), 1, 0),
          jnp.moveaxis(labels.reshape(B, chunks, c), 1, 0))
    _, (losses, counts) = jax.lax.scan(chunk_loss, None, xs)
    total, count = jnp.sum(losses), jnp.maximum(jnp.sum(counts), 1.0)
    return total / count


# matmul weights that every layer casts to bf16 at use anyway — casting
# them ONCE at the top (pinned to their sharding) moves the f32->bf16
# convert outside the layer scan, so FSDP weight gathers and per-layer
# gradient collectives travel in bf16 (numerics-identical: the dots were
# bf16 already; grad accumulation across microbatches stays f32).
# §Perf iteration 3 in EXPERIMENTS.md.
_BF16_CASTABLE = ("embed", "e_gate", "e_up", "e_down")


def _cast_weights_bf16(params, policy: Optional[Policy]):
    specs = policy.param_specs(params) if policy is not None \
        and policy.mesh is not None else None

    def cast(path, p, spec=None):
        names = [k.key for k in path
                 if isinstance(k, jax.tree_util.DictKey)]
        name = names[-1] if names else ""
        parent = names[-2] if len(names) > 1 else ""
        castable = (name == "w" and parent != "dt_proj") \
            or name in _BF16_CASTABLE
        if not (castable and p.dtype == jnp.float32 and p.ndim >= 2):
            return p
        c = p.astype(jnp.bfloat16)
        if spec is not None and policy is not None:
            c = policy.sc(c, spec)      # pin: reshard AFTER the cast
        return c

    if specs is None:
        return jax.tree_util.tree_map_with_path(cast, params)
    return jax.tree_util.tree_map_with_path(cast, params, specs)


def make_loss_fn(cfg, policy: Optional[Policy], opts: StackOpts,
                 aux_coeff: float = 0.01):
    def loss_fn(params, batch):
        if cfg.train.bf16_weight_cast:
            params = _cast_weights_bf16(params, policy)
        x, aux, _, n_prefix = backbone(params, cfg, batch, policy, opts)
        labels = batch["labels"]
        if n_prefix:
            x = x[:, n_prefix:]
        loss = ce_loss(params, cfg, x, labels, cfg.train.loss_seq_chunks)
        total = loss + aux_coeff * aux
        return total, {"loss": loss, "moe_aux": aux}
    return loss_fn


# --------------------------------------------------------------------------
# train step (microbatched grad accumulation + AdamW)
# --------------------------------------------------------------------------


def make_train_step(cfg, policy: Optional[Policy],
                    opt_cfg: adamw.AdamWConfig, *,
                    attn_impl: str = "xla"):
    opts = opts_from_cfg(cfg, attn_impl=attn_impl)
    loss_fn = make_loss_fn(cfg, policy, opts)
    n_micro = max(1, cfg.train.microbatches)

    def shard_grads_2d(tree):
        """Perf iter 2a (EXPERIMENTS.md §Perf): keep the gradient
        accumulator ZeRO-sharded (2D).  An unconstrained accumulator is
        resolved replicated by GSPMD, which all-reduces every layer's
        full f32 grad once per MICROBATCH; the 2D constraint turns that
        into reduce-scatters (half the ring bytes) and feeds the ZeRO
        optimizer shards directly."""
        if policy is None or policy.mesh is None \
                or not cfg.train.grad_2d_accum:
            return tree
        specs = policy.param_specs(tree, use2d=True)
        return jax.tree_util.tree_map(
            lambda x, s: policy.sc(x, s), tree, specs)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = shard_grads_2d(grads)
        else:
            def split(v):
                return v.reshape((n_micro, v.shape[0] // n_micro)
                                 + v.shape[1:])
            micro = jax.tree_util.tree_map(split, batch)
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, F32), params)
            zero_g = shard_grads_2d(zero_g)

            def acc(carry, mb):
                g_sum, l_sum, a_sum = carry
                (l, met), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                # constrain f32 grads 2D right at the backward output so
                # the layer-scan carry resolves sharded (reduce-scatter,
                # not all-reduce); accumulate f32
                g = shard_grads_2d(g)
                g_sum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(F32), g_sum, g)
                return (g_sum, l_sum + l, a_sum + met["moe_aux"]), None

            (g_sum, l_sum, a_sum), _ = jax.lax.scan(
                acc, (zero_g, jnp.zeros((), F32), jnp.zeros((), F32)),
                micro)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, g_sum)
            loss = l_sum / n_micro
            metrics = {"loss": loss, "moe_aux": a_sum / n_micro}
        params, opt_state, opt_metrics = adamw.update(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, **opt_metrics)
        return params, opt_state, metrics

    return train_step


# --------------------------------------------------------------------------
# inference: prefill + single-token decode
# --------------------------------------------------------------------------


def make_prefill(cfg, policy: Optional[Policy], *, decode_len: int,
                 attn_impl: str = "xla"):
    opts = opts_from_cfg(cfg, decode_len=decode_len, attn_impl=attn_impl)

    def prefill(params, batch):
        x, _aux, caches, _ = backbone(params, cfg, batch, policy, opts,
                                      want_cache=True)
        logits = Ly.logits_out(
            params.get("lm_head"), x[:, -1:],
            tied_embed=params["embed"] if cfg.tie_embeddings else None)
        return logits[:, 0], caches
    return prefill


def make_serve_step(cfg, policy: Optional[Policy], *,
                    attn_impl: str = "xla"):
    """One decode step: (params, caches, tokens (B,1), cache_len) ->
    (logits (B,V), new caches).

    ``cache_len`` is a scalar (the one-shot serve loop: whole batch at the
    same position) or a ``(B,)`` vector of per-slot positions (the serving
    engine's continuous batching: each slot decodes at its own length —
    see ``repro.serving``)."""
    opts = opts_from_cfg(cfg, attn_impl=attn_impl)

    def serve_step(params, caches, tokens, cache_len):
        x = Ly.embed_lookup(params["embed"], tokens)      # (B,1,d)
        enc_dummy = None
        x, new_caches = Tf.stack_decode(params["layers"], cfg, x, caches,
                                        cache_len, policy, opts)
        x = Ly.rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = Ly.logits_out(
            params.get("lm_head"), x,
            tied_embed=params["embed"] if cfg.tie_embeddings else None)
        return logits[:, 0], new_caches
    return serve_step


def make_slot_prefill(cfg, policy: Optional[Policy], *, decode_len: int,
                      attn_impl: str = "xla"):
    """Prefill for one continuous-batching slot refill.

    ``(params, batch, length) -> (logits (B,V), caches)`` where
    ``batch['tokens']`` is a fixed-shape *right-padded* prompt ``(B,P)``
    and ``length`` is the true prompt length: logits are taken at
    position ``length - 1`` (the last real token — it attends only to
    real positions under the causal mask) instead of the padded end.
    Padding rows land in cache positions ``>= length`` but stay masked at
    decode (``decode_attention`` masks ``> cache_len``) and are
    overwritten token by token as the slot generates.  One fixed padded
    shape = one jit trace for every prompt length (heterogeneous request
    sizes all hit the cached executable)."""
    opts = opts_from_cfg(cfg, decode_len=decode_len, attn_impl=attn_impl)

    def slot_prefill(params, batch, length):
        x, _aux, caches, n_prefix = backbone(params, cfg, batch, policy,
                                             opts, want_cache=True)
        idx = jnp.asarray(n_prefix + length - 1, jnp.int32)
        xl = jax.lax.dynamic_slice_in_dim(x, idx, 1, axis=1)
        logits = Ly.logits_out(
            params.get("lm_head"), xl,
            tied_embed=params["embed"] if cfg.tie_embeddings else None)
        return logits[:, 0], caches
    return slot_prefill


def write_cache_slot(caches, one, slot):
    """Scatter a single-sequence cache pytree (batch 1, as produced by
    ``make_slot_prefill``) into a running batch cache at batch index
    ``slot`` — the continuous-batching refill: freed slots take a new
    sequence's prefilled KV without touching the other slots.  All cache
    leaves are stacked ``(n_groups, B, ...)``, so the slot axis is 1."""
    def upd(b, o):
        start = (0, slot) + (0,) * (b.ndim - 2)
        return jax.lax.dynamic_update_slice(b, o.astype(b.dtype), start)
    return jax.tree_util.tree_map(upd, caches, one)


# --------------------------------------------------------------------------
# cache shape construction (for dry-run input_specs and serving)
# --------------------------------------------------------------------------


def cache_struct(cfg, batch_size: int, decode_len: int,
                 enc_len: int = 0):
    """Abstract (ShapeDtypeStruct) cache pytree matching stack_apply's
    stacked layout."""
    per = cfg.attn_period if cfg.attn_period > 1 else 1
    n_groups = cfg.n_layers // per
    B = batch_size

    def one(kind_i):
        mixer, _, cross = Tf.layer_kind(cfg, kind_i)
        c = {}
        if mixer == "attn":
            kv = (B, cfg.n_kv_heads, decode_len, cfg.d_head)
            c["k"] = jax.ShapeDtypeStruct(kv, jnp.bfloat16)
            c["v"] = jax.ShapeDtypeStruct(kv, jnp.bfloat16)
        else:
            c["conv"] = jax.ShapeDtypeStruct(
                (B, cfg.ssm_conv - 1, cfg.d_inner), F32)
            c["ssm"] = jax.ShapeDtypeStruct(
                (B, cfg.d_inner, cfg.ssm_state), F32)
        if cross:
            ckv = (B, cfg.n_kv_heads, enc_len, cfg.d_head)
            c["ck"] = jax.ShapeDtypeStruct(ckv, jnp.bfloat16)
            c["cv"] = jax.ShapeDtypeStruct(ckv, jnp.bfloat16)
        return c

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((n_groups,) + s.shape, s.dtype),
            tree)

    if per == 1:
        return stack(one(0))
    return stack({f"sub{j}": one(j) for j in range(per)})
