"""Layer stacks for all assigned architecture families.

A *layer* = (norm -> mixer -> residual) [+ (norm -> ffn -> residual)]
where mixer ∈ {GQA attention, mamba} and ffn ∈ {swiglu, gelu, moe, none}.
Uniform stacks (dense/moe/ssm/vlm, enc/dec halves of audio) are scanned
with stacked params; jamba scans over *periods* of ``attn_period`` layers
(python-unrolled inside the scan body) so the heterogeneous 7:1
mamba:attention interleave still compiles O(period) HLO.

Three traversal modes share the layer definitions:
``train`` (no cache), ``prefill`` (emit per-layer cache), ``decode``
(consume+emit cache, one token).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import layers as Ly
from . import mamba as Mb
from . import moe as Moe

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class StackOpts:
    """Runtime knobs threaded through the stack (from TrainSettings)."""
    attn_impl: str = "xla"
    q_chunk: int = 1024
    k_chunk: int = 1024
    remat: str = "full"          # none | full | dots
    mamba_impl: str = "xla"
    mamba_chunk: int = 128
    moe_capacity: float = 1.25
    decode_len: int = 0          # static cache length for decode/prefill


def layer_kind(cfg, i: int) -> tuple[str, str, bool]:
    """(mixer, ffn, cross) for layer i."""
    mixer = "mamba" if not cfg._layer_has_attention(i) else "attn"
    if cfg._layer_has_moe(i):
        ffn = "moe"
    elif cfg.d_ff > 0:
        ffn = "gelu" if cfg.family == "audio" else "mlp"
    else:
        ffn = "none"
    return mixer, ffn, cfg.is_encdec


# --------------------------------------------------------------------------
# single-layer init / apply
# --------------------------------------------------------------------------


def layer_init(key, cfg, i: int, *, encoder: bool = False):
    mixer, ffn, cross = layer_kind(cfg, i)
    if encoder:
        mixer, ffn, cross = "attn", ("gelu" if cfg.family == "audio"
                                     else "mlp"), False
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": Ly.rms_norm_init(cfg.d_model)}
    if mixer == "attn":
        p["attn"] = Ly.attn_init(ks[0], cfg)
    else:
        p["mamba"] = Mb.mamba_init(ks[0], cfg)
    if cross and not encoder:
        p["ln_cross"] = Ly.rms_norm_init(cfg.d_model)
        p["cross"] = Ly.attn_init(ks[1], cfg, cross=True)
    if ffn != "none":
        p["ln2"] = Ly.rms_norm_init(cfg.d_model)
        if ffn == "moe":
            p["ffn_moe"] = Moe.moe_init(ks[2], cfg)
        elif ffn == "gelu":
            p["ffn_gelu"] = Ly.gelu_mlp_init(ks[2], cfg.d_model, cfg.d_ff,
                                             cfg.n_layers)
        else:
            p["ffn_mlp"] = Ly.swiglu_init(ks[2], cfg.d_model, cfg.d_ff,
                                          cfg.n_layers)
    return p


def _apply_ffn(p, cfg, x, policy, opts, *, decode: bool):
    aux = jnp.zeros((), F32)
    # MLP f-dim pins are a *training* lever (§Perf iter 5); prefill/decode
    # layouts differ and the pins force resharding there (measured)
    mlp_pin = cfg.train.mlp_shard_opt and opts.decode_len == 0 \
        and not decode
    if "ffn_moe" in p:
        h = Ly.rms_norm(p["ln2"], x, cfg.norm_eps)
        y, aux = Moe.moe_apply(p["ffn_moe"], cfg, h, policy, decode=decode,
                               capacity_factor=opts.moe_capacity)
        x = x + y
    elif "ffn_gelu" in p:
        pol = policy if mlp_pin else None
        x = x + Ly.gelu_mlp(p["ffn_gelu"],
                            Ly.rms_norm(p["ln2"], x, cfg.norm_eps),
                            policy=pol)
    elif "ffn_mlp" in p:
        pol = policy if mlp_pin else None
        x = x + Ly.swiglu(p["ffn_mlp"],
                          Ly.rms_norm(p["ln2"], x, cfg.norm_eps),
                          policy=pol)
    return x, aux


def layer_apply(p, cfg, x, positions, policy, opts, *,
                causal: bool = True, enc_out=None, want_cache: bool = False):
    """Full-sequence layer (train / prefill / encoder).

    Returns (x, aux, cache) — cache is {} unless want_cache."""
    cache = {}
    h = Ly.rms_norm(p["ln1"], x, cfg.norm_eps)
    if "attn" in p:
        y, (k, v) = Ly.attn_apply(
            p["attn"], cfg, h, positions, causal=causal,
            attn_impl=opts.attn_impl, q_chunk=opts.q_chunk,
            k_chunk=opts.k_chunk, policy=policy,
            train_mode=not want_cache and opts.decode_len == 0)
        x = x + y
        if want_cache:
            cache["k"], cache["v"] = _cache_pad(k, opts.decode_len), \
                _cache_pad(v, opts.decode_len)
    else:
        # SSM activation pins help training (§Perf iter 4) but force
        # resharding in the prefill layout — train-path only
        y, state = Mb.mamba_apply(
            p["mamba"], cfg, h, impl=opts.mamba_impl,
            scan_chunk=opts.mamba_chunk, return_state=want_cache,
            policy=None if want_cache else policy)
        x = x + y
        if want_cache:
            cache["conv"], cache["ssm"] = state["conv"], state["ssm"]
    if "cross" in p and enc_out is not None:
        hc = Ly.rms_norm(p["ln_cross"], x, cfg.norm_eps)
        yc, (ck, cv) = Ly.attn_apply(
            p["cross"], cfg, hc, positions, causal=False, kv_x=enc_out,
            attn_impl=opts.attn_impl, q_chunk=opts.q_chunk,
            k_chunk=opts.k_chunk, use_rope=False, policy=policy)
        x = x + yc
        if want_cache:
            cache["ck"], cache["cv"] = ck, cv
    x, aux = _apply_ffn(p, cfg, x, policy, opts, decode=False)
    return x, aux, cache


def _cache_pad(k, decode_len: int):
    """Grow prefill kv (B,H,S,D) to the static decode capacity."""
    if decode_len and k.shape[2] < decode_len:
        pad = decode_len - k.shape[2]
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return k


def layer_decode(p, cfg, x, cache, cache_len, policy, opts):
    """One-token decode through one layer; returns (x, new_cache)."""
    h = Ly.rms_norm(p["ln1"], x, cfg.norm_eps)
    if "attn" in p:
        y, new_kv = Ly.attn_decode(p["attn"], cfg, h,
                                   {"k": cache["k"], "v": cache["v"]},
                                   cache_len, policy=policy)
        cache = dict(cache)
        cache.update(new_kv)
        x = x + y
    else:
        y, new_state = Mb.mamba_step(
            p["mamba"], cfg, h, {"conv": cache["conv"],
                                 "ssm": cache["ssm"]})
        cache = dict(cache)
        cache.update(new_state)
        x = x + y
    if "cross" in p:
        hc = Ly.rms_norm(p["ln_cross"], x, cfg.norm_eps)
        yc, _ = Ly.attn_decode(p["cross"], cfg, hc,
                               {"k": cache["ck"], "v": cache["cv"]},
                               cache_len, cross=True, policy=policy)
        x = x + yc
    x, _aux = _apply_ffn(p, cfg, x, policy, opts, decode=True)
    return x, cache


# --------------------------------------------------------------------------
# stacks (scan over layers / periods)
# --------------------------------------------------------------------------


def _period(cfg) -> int:
    return cfg.attn_period if cfg.attn_period > 1 else 1


def stack_init(key, cfg, *, encoder: bool = False):
    n = cfg.encoder_layers if encoder else cfg.n_layers
    per = 1 if encoder else _period(cfg)
    n_groups = n // per
    keys = jax.random.split(key, n_groups)
    if per == 1:
        init_one = partial(layer_init, cfg=cfg, i=0, encoder=encoder)
        return jax.vmap(init_one)(keys)

    def init_period(k):
        ks = jax.random.split(k, per)
        return {f"sub{j}": layer_init(ks[j], cfg, j) for j in range(per)}

    return jax.vmap(init_period)(keys)


def _wrap_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def stack_apply(stack_params, cfg, x, positions, policy, opts, *,
                causal: bool = True, enc_out=None, encoder: bool = False,
                want_cache: bool = False):
    """Scan the stack. Returns (x, aux_sum, stacked_caches | None)."""
    per = 1 if encoder else _period(cfg)

    def body(carry, p_layer):
        x, aux = carry
        if per == 1:
            x, a, cache = layer_apply(p_layer, cfg, x, positions, policy,
                                      opts, causal=causal, enc_out=enc_out,
                                      want_cache=want_cache)
            caches = cache
            aux = aux + a
        else:
            caches = {}
            for j in range(per):
                x, a, cache = layer_apply(
                    p_layer[f"sub{j}"], cfg, x, positions, policy, opts,
                    causal=causal, enc_out=enc_out, want_cache=want_cache)
                caches[f"sub{j}"] = cache
                aux = aux + a
        return (x, aux), caches

    body = _wrap_remat(body, opts.remat)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), F32)),
                                    stack_params)
    return x, aux, (caches if want_cache else None)


def stack_decode(stack_params, cfg, x, caches, cache_len, policy, opts):
    """Decode one token through the whole stack; caches are stacked over
    the scan axis exactly as produced by stack_apply(want_cache=True)."""
    per = _period(cfg)

    def body(x, inp):
        p_layer, cache = inp
        if per == 1:
            x, new_cache = layer_decode(p_layer, cfg, x, cache, cache_len,
                                        policy, opts)
        else:
            new_cache = {}
            for j in range(per):
                x, nc = layer_decode(p_layer[f"sub{j}"], cfg, x,
                                     cache[f"sub{j}"], cache_len, policy,
                                     opts)
                new_cache[f"sub{j}"] = nc
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (stack_params, caches))
    return x, new_caches
