"""Composable model layers (functional; params are nested dicts).

Compute dtype is bf16 (params master fp32, cast at use); softmax, norms
and loss run fp32.  Sharding is annotated by the caller via
``repro.models.sharding.Policy`` — layers stay policy-free.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from . import attention as A

INIT_STD = 0.02


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, bias: bool = False,
               std: float = INIT_STD):
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * std}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(p, x, compute_dtype=jnp.bfloat16):
    y = x.astype(compute_dtype) @ p["w"].astype(compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


def rms_norm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: (B, H, S, D), positions: (B, S) or scalar broadcastable."""
    B, H, S, D = x.shape
    half = D // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if jnp.ndim(positions) == 0:
        positions = jnp.full((B, S), positions)
    ang = positions.astype(jnp.float32)[:, None, :, None] * freq  # (B,1,S,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), \
        x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# GQA attention block
# --------------------------------------------------------------------------


def attn_init(key, cfg, cross: bool = False):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * dh, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, hkv * dh, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, hkv * dh, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], hq * dh, d,
                         std=INIT_STD / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qk_norm:
        p["q_norm"] = rms_norm_init(dh)
        p["k_norm"] = rms_norm_init(dh)
    return p


def _split_heads(y, n_heads, d_head):
    B, S, _ = y.shape
    return y.reshape(B, S, n_heads, d_head).transpose(0, 2, 1, 3)


def attn_apply(p, cfg, x, positions, *, causal=True, kv_x=None,
               attn_impl="xla", q_chunk=1024, k_chunk=1024, use_rope=True,
               policy=None, train_mode=True):
    """Full-sequence attention (train / prefill).  kv_x enables cross-attn.

    Returns (y, (k, v)) — k/v in (B, Hkv, S, D) layout for cache building.
    """
    kv_src = kv_x if kv_x is not None else x
    q = _split_heads(dense(p["wq"], x), cfg.n_heads, cfg.d_head)
    k = _split_heads(dense(p["wk"], kv_src), cfg.n_kv_heads, cfg.d_head)
    v = _split_heads(dense(p["wv"], kv_src), cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    if use_rope and kv_x is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    # GQA sharding (EXPERIMENTS.md §Perf iters 1-2): when Hkv doesn't
    # divide the model axis, unconstrained KV makes GSPMD replicate full
    # f32 score tiles per attention block.  Fix: replicate the (small)
    # KV, then repeat it to Hq *in the q-head-sharded layout* — each
    # device materializes only its own heads' KV, scores stay local, and
    # wq/wo column sharding stays head-aligned so weight grads shard too.
    k_cache, v_cache = k, v                  # caches keep the Hkv layout
    # GQA repeat is a training lever; prefill's cache-building layout is
    # left to GSPMD (measured: pins regress prefill cells)
    attn_policy = policy if (cfg.train.gqa_shard_opt and train_mode) \
        else None
    if attn_policy is not None and attn_policy.mesh is not None:
        world_m = policy.mesh.shape[policy.model_axis]
        if cfg.n_kv_heads % world_m != 0 and kv_x is None:
            G = cfg.n_heads // cfg.n_kv_heads
            b, m = policy.batch_axes, policy.model_axis
            from jax.sharding import PartitionSpec as P
            k = policy.sc(k, P(b, None, None, None))     # replicated
            v = policy.sc(v, P(b, None, None, None))
            k = policy.sc(jnp.repeat(k, G, axis=1), P(b, m, None, None))
            v = policy.sc(jnp.repeat(v, G, axis=1), P(b, m, None, None))
            q = policy.shard_heads(q)
    elif policy is not None:
        # paper-faithful baseline lowering (gqa_shard_opt=False)
        q, k, v = policy.shard_heads(q), policy.shard_kv(k), \
            policy.shard_kv(v)
    o = A.attention(q, k, v, causal=causal, impl=attn_impl,
                    q_chunk=q_chunk, k_chunk=k_chunk, policy=attn_policy)
    B, S = x.shape[:2]
    y = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.d_head)
    return dense(p["wo"], y), (k_cache, v_cache)


def attn_decode(p, cfg, x, cache, cache_len, *, cross=False, policy=None):
    """One-token decode.  cache = {"k","v"} (B,Hkv,S,D); for cross
    attention the cache holds the (static) encoder memory.

    ``cache_len`` is a scalar or a ``(B,)`` vector of per-slot positions
    (continuous batching: each sequence in the batch decodes at its own
    length — the write, rope phase, and mask are all per-slot)."""
    q = _split_heads(dense(p["wq"], x), cfg.n_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
    if not cross:
        cl = jnp.asarray(cache_len)
        pos = cl if cl.ndim == 0 else cl[:, None]        # rope: (B,1)
        k_new = _split_heads(dense(p["wk"], x), cfg.n_kv_heads, cfg.d_head)
        v_new = _split_heads(dense(p["wv"], x), cfg.n_kv_heads, cfg.d_head)
        if cfg.qk_norm:
            k_new = rms_norm(p["k_norm"], k_new, cfg.norm_eps)
        q = rope(q, pos, cfg.rope_theta)
        k_new = rope(k_new, pos, cfg.rope_theta)
        # one-hot scatter write (shard-friendly on a sharded S axis)
        S = cache["k"].shape[2]
        if cl.ndim == 0:
            onehot = (jnp.arange(S) == cl).astype(cache["k"].dtype)
            oh = onehot[None, None, :, None]
        else:                       # per-slot write position: (B,1,S,1)
            onehot = (jnp.arange(S)[None, :] == cl[:, None]) \
                .astype(cache["k"].dtype)
            oh = onehot[:, None, :, None]
        cache = {
            "k": cache["k"] * (1 - oh) + k_new.astype(cache["k"].dtype) * oh,
            "v": cache["v"] * (1 - oh) + v_new.astype(cache["v"].dtype) * oh,
        }
        live_len = cache_len
    else:
        live_len = cache["k"].shape[2] - 1          # full encoder memory
    o = A.decode_attention(q, cache["k"], cache["v"], live_len)
    B = x.shape[0]
    y = o.transpose(0, 2, 1, 3).reshape(B, 1, cfg.n_heads * cfg.d_head)
    return dense(p["wo"], y), cache


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def swiglu_init(key, d: int, f: int, n_layers: int):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d, f),
        "w_up": dense_init(ks[1], d, f),
        "w_down": dense_init(ks[2], f, d,
                             std=INIT_STD / math.sqrt(2 * n_layers)),
    }


def swiglu(p, x, policy=None):
    g = jax.nn.silu(dense(p["w_gate"], x))
    u = dense(p["w_up"], x)
    if policy is not None and policy.mesh is not None:
        # pin the f-dim to the model axis: without it GSPMD can resolve
        # the intermediate replicated inside period-stacked scan bodies,
        # which replicates the MLP weight grads (§Perf iter 5)
        from jax.sharding import PartitionSpec as P
        sp = P(policy.batch_axes, None, policy.model_axis)
        g, u = policy.sc(g, sp), policy.sc(u, sp)
    return dense(p["w_down"], g * u)


def gelu_mlp_init(key, d: int, f: int, n_layers: int):
    ks = jax.random.split(key, 2)
    return {
        "w_in": dense_init(ks[0], d, f),
        "w_out": dense_init(ks[1], f, d,
                            std=INIT_STD / math.sqrt(2 * n_layers)),
    }


def gelu_mlp(p, x, policy=None):
    h = jax.nn.gelu(dense(p["w_in"], x))
    if policy is not None and policy.mesh is not None:
        from jax.sharding import PartitionSpec as P
        h = policy.sc(h, P(policy.batch_axes, None, policy.model_axis))
    return dense(p["w_out"], h)


# --------------------------------------------------------------------------
# Embedding / logits
# --------------------------------------------------------------------------


def embed_init(key, vocab: int, d: int):
    return {"embed": jax.random.normal(key, (vocab, d), jnp.float32)
            * INIT_STD}


def embed_lookup(p, tokens, compute_dtype=jnp.bfloat16):
    return p["embed"].astype(compute_dtype)[tokens]


def logits_out(p_head, x, tied_embed=None):
    """x (B,S,d) -> logits fp32 (B,S,V)."""
    if tied_embed is not None:
        w = tied_embed["embed"].astype(jnp.bfloat16).T
    else:
        w = p_head["w"].astype(jnp.bfloat16)
    return jax.lax.dot_general(
        x.astype(jnp.bfloat16), w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
