"""Attention compute paths.

Three implementations share one contract (``q (B,Hq,Sq,D)``, ``k/v
(B,Hkv,Skv,D)`` -> ``(B,Hq,Sq,D)``):

* ``full``    — one einsum; used when the score matrix is small;
* ``chunked`` — online-softmax over (q-chunk, kv-chunk) tiles expressed as
  ``lax.scan`` (the XLA-native flash attention used by the dry-run and the
  long-context shapes; per-step score tiles are ``jax.checkpoint``-ed so
  the backward never materializes the full score matrix);
* ``pallas``  — the fused ``kernels/flash_attention`` TPU kernel.

GQA is computed without repeating KV in HBM: q is grouped as
``(B, Hkv, G, Sq, D)`` and contracted against ungrouped KV.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels.flash_attention import flash_attention as _pallas_flash

NEG_INF = -1e30


def _causal_mask(sq: int, skv: int, q_off, k_off):
    qi = jnp.arange(sq)[:, None] + q_off
    kj = jnp.arange(skv)[None, :] + k_off
    return kj <= qi                                       # (sq, skv) bool


def full_attention(q, k, v, *, causal: bool = True, scale=None,
                   policy=None):
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32) * scale
    if policy is not None:
        qg, k, v = policy.shard_gqa_grouped(qg, k, v)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    if causal:
        s = jnp.where(_causal_mask(Sq, Skv, Skv - Sq, 0)[None, None, None],
                      s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool = True, q_chunk: int = 1024,
                      k_chunk: int = 1024, scale=None, policy=None):
    """Flash-style online softmax with lax.scan tiling (XLA path)."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv

    def _divisor_chunk(n, target):
        c = min(target, n)
        while n % c != 0:
            c -= 1
        return c

    qc = _divisor_chunk(Sq, q_chunk)
    kc = _divisor_chunk(Skv, k_chunk)
    nq, nk = Sq // qc, Skv // kc
    scale = scale if scale is not None else D ** -0.5

    if policy is not None:
        # constrain the grouped layout BEFORE tiling so every scan step
        # works on locally-sharded tiles (no involuntary score gathers)
        qg5 = q.reshape(B, Hkv, G, Sq, D)
        qg5, k, v = policy.shard_gqa_grouped(qg5, k, v)
        q = qg5.reshape(B, Hq, Sq, D)
    qg = (q.reshape(B, Hkv, G, nq, qc, D).astype(jnp.float32) * scale)
    qg = jnp.moveaxis(qg, 3, 0)                      # (nq, B, Hkv, G, qc, D)
    ks = jnp.moveaxis(k.reshape(B, Hkv, nk, kc, D), 2, 0)
    vs = jnp.moveaxis(v.reshape(B, Hkv, nk, kc, D), 2, 0)

    @jax.checkpoint
    def kv_step(carry, inp, qb, q_off):
        m, l, acc = carry
        kb, vb, k_off = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb.astype(jnp.float32))
        if causal:
            mask = _causal_mask(qb.shape[-2], kb.shape[-2],
                                q_off + (Skv - Sq), k_off)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    def q_step(_, inp):
        qb, qi = inp                                  # (B,Hkv,G,qc,D)
        m0 = jnp.full(qb.shape[:-1], NEG_INF, jnp.float32)
        l0 = jnp.zeros(qb.shape[:-1], jnp.float32)
        a0 = jnp.zeros(qb.shape, jnp.float32)
        k_offs = jnp.arange(nk) * kc
        (m, l, acc), _ = jax.lax.scan(
            functools.partial(kv_step, qb=qb, q_off=qi * qc),
            (m0, l0, a0), (ks, vs, k_offs))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, o

    _, outs = jax.lax.scan(q_step, None, (qg, jnp.arange(nq)))
    # outs: (nq, B, Hkv, G, qc, D)
    o = jnp.moveaxis(outs, 0, 3).reshape(B, Hkv, G, Sq, D)
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def attention(q, k, v, *, causal: bool = True, impl: str = "xla",
              q_chunk: int = 1024, k_chunk: int = 1024, policy=None):
    """Dispatching entry point used by the model layers."""
    if impl == "pallas":
        return _pallas_flash(q, k, v, causal=causal, impl="pallas")
    if impl == "pallas_interpret":
        return _pallas_flash(q, k, v, causal=causal, impl="pallas_interpret",
                             bq=min(128, q.shape[2]), bk=min(128, k.shape[2]))
    Sq, Skv = q.shape[2], k.shape[2]
    if Sq <= q_chunk and Skv <= k_chunk:
        return full_attention(q, k, v, causal=causal, policy=policy)
    return chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                             k_chunk=k_chunk, policy=policy)


def decode_attention(q, k_cache, v_cache, cache_len):
    """Single-token decode: q (B,Hq,1,D) vs cache (B,Hkv,S,D).

    Positions ``>= cache_len + 1`` (i.e. beyond the just-written token) are
    masked.  ``cache_len`` is a scalar (whole batch at one position) or a
    ``(B,)`` vector (continuous batching: every slot at its own position —
    the serving engine's per-slot decode).  Shard-friendly: reductions over
    the cache S axis lower to (all-)reduces when S is sharded — the
    flash-decoding pattern falls out of GSPMD automatically.
    """
    B, Hq, _, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_cache.astype(jnp.float32))
    cl = jnp.asarray(cache_len)
    if cl.ndim:                       # per-slot lengths: (B,) -> (B,1,1,1)
        cl = cl[:, None, None, None]
    live = jnp.arange(S)[None, None, None, :] <= cl
    s = jnp.where(live, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, 1, D).astype(q.dtype)
