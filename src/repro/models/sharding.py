"""Sharding policy: parameter/activation PartitionSpecs by tree path.

One rule table covers all architectures; specs are derived from leaf names
(``wq``, ``e_gate``, ``in_proj``...) and left-padded with ``None`` for
stacked-layer leading axes, so the same rules apply to scanned stacks and
jamba period stacks.

Flavors:
* ``tp``      — 1D tensor parallelism over ``model``; params replicated
  over data (classic Megatron).
* ``fsdp_tp`` — 2D: the non-model matrix dim is additionally sharded over
  ``data`` (FSDP-style per-layer all-gather, and what serving uses to fit
  big weights).
Optimizer state always uses the 2D layout (ZeRO-1) when
``TrainSettings.use_zero1``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Policy:
    mesh: Mesh | None = None
    flavor: str = "tp"                  # tp | fsdp_tp
    model_axis: str = "model"
    batch_axes: tuple[str, ...] = ("data",)

    # ---------------------------------------------------------------- utils
    def sc(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def shard_activations(self, x):
        """(B, S, d) batch-sharded."""
        return self.sc(x, P(self.batch_axes, None, None))

    def shard_heads(self, q):
        """(B, H, S, D): heads over model."""
        return self.sc(q, P(self.batch_axes, self.model_axis, None, None))

    def shard_kv(self, k):
        return k  # few KV heads: let GSPMD propagate (avoid forced padding)

    def shard_gqa_grouped(self, qg, k, v):
        """Grouped GQA layout (perf iteration 1, EXPERIMENTS.md §Perf).

        qg (B, Hkv, G, S, D); k/v (B, Hkv, S, D).  When Hkv < |model|,
        unconstrained KV makes GSPMD 'involuntarily rematerialize' the
        f32 score tiles (full all-gathers per attention tile per layer).
        Fix: shard the GROUP axis of q over model and replicate KV —
        scores become fully local; the only added traffic is the small
        KV broadcast."""
        if self.mesh is None:
            return qg, k, v
        m = self.model_axis
        world_m = self.mesh.shape[m]
        hkv, g = qg.shape[1], qg.shape[2]
        b = self.batch_axes
        if hkv % world_m == 0:
            # enough KV heads: classic head sharding everywhere
            qg = self.sc(qg, P(b, m, None, None, None))
            k = self.sc(k, P(b, m, None, None))
            v = self.sc(v, P(b, m, None, None))
        elif g % world_m == 0:
            qg = self.sc(qg, P(b, None, m, None, None))
            k = self.sc(k, P(b, None, None, None))       # replicated
            v = self.sc(v, P(b, None, None, None))
        elif (hkv * g) % world_m == 0:
            # split model over (kv, group) jointly via reshape-free 2-axis
            # constraint is inexpressible; fall back to group sharding of
            # the combined axis by constraining q's flat head layout
            qg = self.sc(qg, P(b, None, m, None, None))
            k = self.sc(k, P(b, None, None, None))
            v = self.sc(v, P(b, None, None, None))
        return qg, k, v

    def named(self, spec: P) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, spec)

    # ------------------------------------------------------- parameter rules
    def _dd(self, use2d: bool):
        return "data" if use2d else None

    def base_spec(self, names: tuple[str, ...], ndim_hint: int,
                  use2d: bool) -> tuple:
        m = self.model_axis
        dd = self._dd(use2d)
        name = names[-1]
        parent = names[-2] if len(names) > 1 else ""
        if name == "embed":
            return (m, dd)
        if name == "scale":
            return ()
        if parent == "lm_head" and name == "w":
            return (dd, m)
        if name == "b":
            if parent in ("wq", "wk", "wv", "in_proj", "dt_proj"):
                return (m,)
            return (None,)
        if parent in ("wq", "wk", "wv", "w_gate", "w_up", "w_in",
                      "in_proj") and name == "w":
            return (dd, m)
        if parent in ("wo", "w_down", "w_out", "out_proj") and name == "w":
            return (m, dd)
        if parent == "x_proj" and name == "w":
            return (m, None)
        if parent == "dt_proj" and name == "w":
            return (None, m)
        if name == "router":
            return (None, None)
        if name in ("e_gate", "e_up"):
            return (m, dd, None)
        if name == "e_down":
            return (m, None, dd)
        if name == "conv_w":
            return (None, m)
        if name in ("conv_b", "D"):
            return (m,)
        if name == "A_log":
            return (m, None)
        return tuple([None] * ndim_hint)

    def param_specs(self, params_shape: Any, *, for_opt: bool = False,
                    use2d: bool | None = None):
        """Pytree of PartitionSpecs matching a params(-shaped) pytree."""
        if use2d is None:
            use2d = (self.flavor == "fsdp_tp") or for_opt

        def one(path, leaf):
            names = tuple(
                p.key for p in path
                if isinstance(p, jax.tree_util.DictKey))
            ndim = len(leaf.shape)
            base = self.base_spec(names, ndim, use2d)
            pad = ndim - len(base)
            if pad < 0:          # scalar leaves (e.g. step counters)
                return P()
            return P(*([None] * pad + list(base)))

        return jax.tree_util.tree_map_with_path(one, params_shape)

    def param_shardings(self, params_shape: Any, **kw):
        specs = self.param_specs(params_shape, **kw)
        return jax.tree_util.tree_map(self.named, specs,
                                      is_leaf=lambda s: isinstance(s, P))


def make_policy(mesh: Mesh | None, flavor: str = "tp") -> Policy:
    if mesh is None:
        return Policy(mesh=None, flavor=flavor)
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    model_axis = "model" if "model" in names else names[-1]
    if not batch_axes:
        batch_axes = tuple(a for a in names if a != model_axis)[:1]
    return Policy(mesh=mesh, flavor=flavor, model_axis=model_axis,
                  batch_axes=batch_axes)
