from .ddp import make_ddp_train_step  # noqa: F401
from .trainer import (  # noqa: F401
    FailureInjector, StepTimeMonitor, Trainer, run_with_restarts,
)
