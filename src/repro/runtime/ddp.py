"""Explicit BSP distributed-data-parallel training (paper §3.3, Listings
4/6): the Horovod/PyTorch-DDP pattern as one shard_map program.

Params are replicated; each worker grads its local batch shard; gradients
are combined with ``pmean`` (exact) or the compressed error-feedback
allreduce (paper's Horovod compression); the optimizer update is computed
redundantly-but-identically on every worker (classic DDP).

This is the path the UNOMT application and the 100M-LM example use — the
giant-model configs use the GSPMD train_step (models.model) instead.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.context import HptmtContext, shard_map
from ..optim import adamw, compression


def make_ddp_train_step(loss_fn: Callable, opt_cfg: adamw.AdamWConfig,
                        ctx: HptmtContext, *, compress: bool = False):
    """loss_fn(params, batch) -> (loss, metrics-dict of scalars).

    Returns jitted ``step(params, opt_state, residuals, global_batch)`` ->
    (params, opt_state, residuals, metrics).  ``global_batch`` leaves are
    batch-sharded over ctx.row_axes; params/opt replicated."""
    axes = ctx.row_axes
    world = ctx.world_size
    mesh = ctx.mesh

    def local_step(params, opt_state, residuals, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        if compress:
            grads, residuals = compression.compressed_grad_allreduce(
                grads, residuals, axes, world)
        else:
            grads = jax.tree_util.tree_map(
                lambda g: jax.lax.pmean(g, axes), grads)
        params, opt_state, om = adamw.update(params, grads, opt_state,
                                             opt_cfg)
        metrics = dict(metrics, **om)
        metrics["loss"] = jax.lax.pmean(loss, axes)
        return params, opt_state, residuals, metrics

    rep = P()
    bspec = P(axes)
    step = shard_map(
        local_step, mesh=mesh,
        in_specs=(rep, rep, rep, bspec),
        out_specs=(rep, rep, rep, rep))
    return jax.jit(step, donate_argnums=(0, 1, 2))
