"""Fault-tolerant training runtime.

The BSP training loop with the operational features a 1000+-node fleet
needs (DESIGN.md §4):

* **checkpoint/restart** — async checkpoints every N steps; the loop is
  wrapped in :func:`run_with_restarts` which restores the latest
  checkpoint after a (simulated or real) worker failure and continues —
  end state is bit-identical to an uninterrupted run (tested).
* **failure injection** — :class:`FailureInjector` raises at a chosen
  step to exercise the restart path in tests/drills.
* **straggler detection** — :class:`StepTimeMonitor` keeps an EWMA of
  step wall-time and flags outliers; the hook is where a fleet manager
  would trigger hot-spare swap; for *data-skew* stragglers (the common
  case for table pipelines) the mitigation is the distributed
  ``repartition`` operator (core.dist_ops.dist_repartition).
* **elastic scaling** — checkpoints are mesh-agnostic; `Trainer.restore`
  re-shards onto the live mesh (checkpoint.store).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from ..checkpoint import AsyncCheckpointer, latest_step, restore


class FailureInjector:
    """Raises RuntimeError once when the step counter hits `fail_at`."""

    def __init__(self, fail_at: int | None = None):
        self.fail_at = fail_at
        self.fired = False

    def check(self, step: int):
        if self.fail_at is not None and not self.fired \
                and step == self.fail_at:
            self.fired = True
            raise RuntimeError(f"injected failure at step {step}")


class StepTimeMonitor:
    """EWMA step-time tracker with straggler flagging."""

    def __init__(self, alpha: float = 0.1, threshold: float = 2.0):
        self.alpha = alpha
        self.threshold = threshold
        self.mean: float | None = None
        self.stragglers: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        if self.mean is None:
            self.mean = dt
            return False
        is_straggler = dt > self.threshold * self.mean
        if is_straggler:
            self.stragglers.append((step, dt))
        self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
        return is_straggler


@dataclasses.dataclass
class Trainer:
    """Generic checkpointed training loop over a jitted step function.

    step_fn(state, batch) -> (state, metrics); state is any pytree
    (params/opt/residuals).
    """

    step_fn: Callable
    ckpt_dir: str
    ckpt_every: int = 50
    keep_last: int = 3
    failure: Optional[FailureInjector] = None
    monitor: StepTimeMonitor = dataclasses.field(
        default_factory=StepTimeMonitor)

    def restore_or_init(self, init_state):
        if latest_step(self.ckpt_dir) is not None:
            step, state = restore(self.ckpt_dir, init_state)
            return step, state
        return 0, init_state

    def run(self, state, batches: Iterator, n_steps: int,
            start_step: int = 0, log_every: int = 10,
            log_fn=print) -> tuple[Any, list[dict]]:
        ckpt = AsyncCheckpointer(self.ckpt_dir, keep_last=self.keep_last)
        history = []
        step = start_step
        for batch in batches:
            if step >= n_steps:
                break
            t0 = time.time()
            if self.failure is not None:
                self.failure.check(step)
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics)
            dt = time.time() - t0
            straggler = self.monitor.record(step, dt)
            step += 1
            if step % self.ckpt_every == 0 or step == n_steps:
                ckpt.save(step, state)
            rec = {k: float(np.asarray(v)) for k, v in metrics.items()}
            rec["step"] = step
            rec["dt"] = dt
            rec["straggler"] = straggler
            history.append(rec)
            if log_every and step % log_every == 0:
                log_fn(f"step {step}: " + " ".join(
                    f"{k}={v:.4f}" for k, v in rec.items()
                    if isinstance(v, float)))
        ckpt.wait()
        return state, history


def run_with_restarts(make_batches: Callable[[int], Iterator],
                      trainer: Trainer, init_state, n_steps: int,
                      max_restarts: int = 3, log_fn=print):
    """Drive Trainer.run with automatic restore-on-failure.

    ``make_batches(start_step)`` must return an iterator positioned at
    ``start_step`` (deterministic data order — the synthetic pipelines
    here are seeded by step)."""
    # Snapshot step 0 before training: step functions donate their input
    # buffers, so a failure BEFORE the first periodic checkpoint must not
    # fall back to the (already-donated) init_state.
    from ..checkpoint import save
    if latest_step(trainer.ckpt_dir) is None:
        save(trainer.ckpt_dir, 0, init_state,
             keep_last=trainer.keep_last)
    attempts = 0
    while True:
        start, state = trainer.restore_or_init(init_state)
        try:
            return trainer.run(state, make_batches(start), n_steps,
                               start_step=start, log_fn=log_fn)
        except RuntimeError as e:
            attempts += 1
            log_fn(f"[fault] {e} -> restart {attempts}/{max_restarts}")
            if attempts > max_restarts:
                raise
