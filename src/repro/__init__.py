"""repro: HPTMT Parallel Operators in JAX (see DESIGN.md)."""
__version__ = "0.1.0"
