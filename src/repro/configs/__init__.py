from .base import (  # noqa: F401
    ARCH_IDS, LONG_CONTEXT_ARCHS, SHAPES, ArchConfig, ShapeCell,
    TrainSettings, cells_for, get_config, get_reduced,
)
