"""Falcon-Mamba-7B [ssm] — 64L d_model=4096 attention-free, ssm_state=16,
vocab=65024 (mamba-1 architecture).  [arXiv:2410.05355; unverified-tier]

Attention-free => long_500k RUNS (O(1)-state decode); the paper's
attention-sharding discussion is inapplicable, but the HPTMT operator
substrate (data pipeline, DP training, shuffle) applies unchanged
(DESIGN.md §5)."""
import dataclasses

from .base import ArchConfig, TrainSettings

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,                       # mamba block replaces attn+ffn
    vocab=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    dt_rank=256,
    train=TrainSettings(microbatches=2),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, vocab=512, ssm_state=8, dt_rank=8,
        train=TrainSettings())
