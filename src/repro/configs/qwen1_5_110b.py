"""Qwen1.5-110B [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-110B family; hf-tier]"""
import dataclasses

from .base import ArchConfig, TrainSettings

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=49152,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    train=TrainSettings(microbatches=8, sharding="fsdp_tp",
                        loss_seq_chunks=4,
                        gqa_shard_opt=False, mlp_shard_opt=False),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=256, vocab=512, train=TrainSettings())
