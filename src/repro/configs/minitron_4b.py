"""Minitron-4B [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned Nemotron.  [arXiv:2407.14679; hf-tier]

Note: 24 heads are not divisible by the model axis (16) — GSPMD pads;
measured in the roofline (DESIGN.md §5)."""
import dataclasses

from .base import ArchConfig, TrainSettings

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_head=128,
    d_ff=9216,
    vocab=256000,
    train=TrainSettings(microbatches=2, loss_seq_chunks=4,
                        gqa_shard_opt=False, mlp_shard_opt=False),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_head=16,
        d_ff=256, vocab=512, train=TrainSettings())
