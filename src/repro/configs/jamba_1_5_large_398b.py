"""Jamba-1.5-Large-398B [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, Mamba:attention 7:1 interleave, MoE 16e top-2
every 2nd layer.  [arXiv:2403.19887; hf-tier]

Hybrid => long_500k RUNS: mamba layers carry the long context with O(1)
state; the 9 attention layers keep a (sharded) 524k KV cache."""
import dataclasses

from .base import ArchConfig, TrainSettings

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab=65536,
    n_experts=16,
    top_k=2,
    d_expert_ff=24576,
    attn_period=8,                # layer 7 of each 8-block is attention
    moe_period=2,                 # odd layers are MoE
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    dt_rank=512,
    train=TrainSettings(microbatches=8, sharding="fsdp_tp",
                        opt_dtype="bfloat16"),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, attn_period=2, moe_period=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, n_experts=8, top_k=2,
        d_expert_ff=128, vocab=512, ssm_state=8, dt_rank=8,
        train=TrainSettings())
