"""~100M-class dense LM for the end-to-end training example
(examples/train_lm.py): 12L d_model=768 12H d_ff=3072, tied embeddings."""
import dataclasses

from .base import ArchConfig, TrainSettings

CONFIG = ArchConfig(
    name="lm100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab=32768,
    tie_embeddings=True,
    train=TrainSettings(microbatches=1),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
        d_ff=512, vocab=1024, train=TrainSettings())
