"""SeamlessM4T-Large-v2 [audio] — enc-dec, 24+24L d_model=1024 16H
(kv=16 -> MHA) d_ff=8192 vocab=256206.  [arXiv:2308.11596; hf-tier]

Backbone only: the speech frontend is a stub — ``input_specs()`` provides
precomputed frame embeddings (seq // enc_len_ratio frames) for the
encoder.  The decoder is a standard causal LM with cross-attention, so the
decode shapes lower ``serve_step`` against the decoder."""
import dataclasses

from .base import ArchConfig, TrainSettings

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,                  # decoder layers
    encoder_layers=24,
    enc_len_ratio=4,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=8192,
    vocab=256206,
    frontend="audio",
    train=TrainSettings(microbatches=1, loss_seq_chunks=4),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, encoder_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=256, vocab=512,
        train=TrainSettings())
