"""Granite-3.0-2B [dense] — 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155.  [hf:ibm-granite/granite-3.0-2b-base; hf-tier]"""
import dataclasses

from .base import ArchConfig, TrainSettings

CONFIG = ArchConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_head=64,
    d_ff=8192,
    vocab=49155,
    tie_embeddings=True,
    train=TrainSettings(microbatches=1,
                        gqa_shard_opt=False, mlp_shard_opt=False),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=256, vocab=512, train=TrainSettings())
