"""Qwen3-MoE-235B-A22B [moe] — 94L d_model=4096 64H (GQA kv=4)
expert d_ff=1536 vocab=151936, MoE 128 experts top-8, QK-norm.
[hf:Qwen/Qwen3-235B-A22B family; hf-tier]

This is the hero cell for the paper's technique: MoE dispatch is the HPTMT
table Shuffle operator (DESIGN.md §2)."""
import dataclasses

from .base import ArchConfig, TrainSettings

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=0,                       # every layer is MoE (no dense FFN)
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128,
    top_k=8,
    d_expert_ff=1536,
    train=TrainSettings(microbatches=4, sharding="fsdp_tp",
                        opt_dtype="bfloat16", loss_seq_chunks=4),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        vocab=512, n_experts=8, top_k=2, d_expert_ff=64,
        train=TrainSettings())
