"""Architecture + run configuration schema and registry.

Every assigned architecture defines one module in ``repro.configs`` with a
``CONFIG: ArchConfig`` at the exact published sizes and a ``reduced()``
smoke-test variant of the same family.  ``--arch <id>`` resolves through
:func:`get_config`.
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    """Per-arch runtime policy (sharding/memory knobs, hillclimb levers)."""

    microbatches: int = 1              # gradient-accumulation steps
    remat: str = "full"                # none | full | dots
    sharding: str = "tp"               # tp | fsdp_tp (2D weight sharding)
    opt_dtype: str = "float32"         # adam moment dtype (bf16 for 398B)
    use_zero1: bool = True             # shard optimizer state over data
    moe_capacity_factor: float = 2.0
    attn_q_chunk: int = 2048           # xla flash chunking
    attn_k_chunk: int = 2048
    loss_seq_chunks: int = 1           # chunk CE loss over seq (memory lever)
    # --- beyond-paper perf levers (EXPERIMENTS.md §Perf; all default ON,
    # set False to reproduce the paper-faithful baseline lowering) ---
    gqa_shard_opt: bool = True         # grouped-GQA sharding + local KV repeat
    bf16_weight_cast: bool = True      # cast matmul weights bf16 at the top
    grad_2d_accum: bool = True         # ZeRO-2D grad accumulator constraint
    ssm_shard_opt: bool = True         # shard mamba activations' E dim over
                                       # model (stops GSPMD replicating
                                       # in_proj/out_proj + their grads)
    mlp_shard_opt: bool = True         # pin swiglu/gelu f-dim to model


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                        # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int                       # 0 => attention-free
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert_ff: int = 0
    n_shared_experts: int = 0
    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0
    # hybrid interleave (jamba): attention every `attn_period` layers,
    # MoE every `moe_period` layers
    attn_period: int = 0
    moe_period: int = 0
    # enc-dec
    encoder_layers: int = 0
    enc_len_ratio: int = 4             # encoder frames = seq // ratio
    # modality frontend stub
    frontend: str = "none"             # none | vision | audio
    frontend_tokens: int = 0           # vision: patch tokens prepended
    # training policy
    train: TrainSettings = TrainSettings()

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.n_heads == 0

    def padded_vocab(self, multiple: int = 256) -> int:
        return math.ceil(self.vocab / multiple) * multiple

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, V = self.d_model, self.padded_vocab()
        total = V * d                                   # embed
        if not self.tie_embeddings:
            total += d * V                              # lm_head
        layers = []
        n_dec = self.n_layers
        for i in range(n_dec):
            layers.append(self._layer_params(i))
        total += sum(layers)
        if self.is_encdec:
            enc_layer = (4 * self.n_heads * self.d_head * d
                         + 2 * d * self.d_ff + 2 * d)
            total += self.encoder_layers * enc_layer
        return total

    def _layer_params(self, i: int) -> int:
        d = self.d_model
        n = 0
        if self._layer_has_attention(i):
            hq = self.n_heads * self.d_head
            hkv = self.n_kv_heads * self.d_head
            n += d * hq + 2 * d * hkv + hq * d
            if self.qkv_bias:
                n += hq + 2 * hkv
            if self.is_encdec:            # decoder cross-attention
                n += d * hq + 2 * d * hkv + hq * d + d
        else:                              # mamba block
            E, N, K = self.d_inner, self.ssm_state, self.ssm_conv
            dtr = self.dt_rank or max(1, math.ceil(d / 16))
            n += d * 2 * E + K * E + E * (dtr + 2 * N) + dtr * E \
                + E * N + E + E * d
        if self._layer_has_moe(i):
            f = self.d_expert_ff or self.d_ff
            n += d * self.n_experts \
                + self.n_experts * 3 * d * f \
                + self.n_shared_experts * 3 * d * f
        elif self.d_ff > 0:
            n += 3 * d * self.d_ff if self.family != "audio" \
                else 2 * d * self.d_ff
        n += 2 * d                                       # norms
        return n

    def _layer_has_attention(self, i: int) -> bool:
        if self.attention_free:
            return False
        if self.attn_period > 1:        # jamba: one attn layer per period
            return (i % self.attn_period) == (self.attn_period - 1)
        return True

    def _layer_has_moe(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        if self.moe_period > 1:
            return (i % self.moe_period) == 1
        return True

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        f = self.d_expert_ff or self.d_ff
        total = self.param_count()
        for i in range(self.n_layers):
            if self._layer_has_moe(i):
                inactive = (self.n_experts - self.top_k) * 3 * d * f
                total -= inactive
        return total


# --------------------------------------------------------------------------
# Input-shape cells (assigned): every LM arch is paired with these four.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                           # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

# archs whose every layer is quadratic full attention skip long_500k
# (no sub-quadratic path; see DESIGN.md §5)
LONG_CONTEXT_ARCHS = ("falcon-mamba-7b", "jamba-1.5-large-398b")


ARCH_IDS = (
    "qwen1.5-110b",
    "minitron-4b",
    "mistral-large-123b",
    "granite-3-2b",
    "qwen3-moe-235b-a22b",
    "granite-moe-3b-a800m",
    "internvl2-2b",
    "seamless-m4t-large-v2",
    "falcon-mamba-7b",
    "jamba-1.5-large-398b",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}
_MODULES["unomt"] = "unomt"
_MODULES["lm100m"] = "lm100m"


def get_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_reduced(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.reduced()


def cells_for(arch: str) -> Sequence[str]:
    if arch in ("unomt", "lm100m"):
        return ("train_4k",)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        cells.append("long_500k")
    return tuple(cells)
