"""InternVL2-2B [vlm] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT frontend + InternLM2 backbone.
[arXiv:2404.16821; hf-tier]

Per the assignment, only the transformer BACKBONE is modeled; the vision
frontend is a stub: ``input_specs()`` provides precomputed patch
embeddings (256 tokens) prepended to the text sequence."""
import dataclasses

from .base import ArchConfig, TrainSettings

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=92553,
    frontend="vision",
    frontend_tokens=256,
    train=TrainSettings(microbatches=1,
                        gqa_shard_opt=False, mlp_shard_opt=False),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=256, vocab=512, frontend_tokens=16, train=TrainSettings())
