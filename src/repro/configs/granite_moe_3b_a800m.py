"""Granite-3.0-MoE-3B-A800M [moe] — 32L d_model=1536 24H (GQA kv=8)
expert d_ff=512 vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-3b-a800m-base family; hf-tier]

Assignment note: the header field says 40 experts, the trailing comment
says 32 — the explicit config field (40) wins (DESIGN.md §5).  40 experts
over a model axis of 16 relies on GSPMD padding (measured in roofline)."""
import dataclasses

from .base import ArchConfig, TrainSettings

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_head=64,
    d_ff=0,
    vocab=49155,
    tie_embeddings=True,
    n_experts=40,
    top_k=8,
    d_expert_ff=512,
    train=TrainSettings(microbatches=1, moe_capacity_factor=1.25),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        vocab=512, n_experts=8, top_k=2, d_expert_ff=64,
        train=TrainSettings())
