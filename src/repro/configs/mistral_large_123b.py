"""Mistral-Large-123B [dense] — 88L d_model=12288 96H (GQA kv=8)
d_ff=28672 vocab=32768.  [hf:mistralai/Mistral-Large-Instruct-2407;
unverified-tier]"""
import dataclasses

from .base import ArchConfig, TrainSettings

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab=32768,
    rope_theta=1_000_000.0,
    train=TrainSettings(microbatches=8, sharding="fsdp_tp",
                        gqa_shard_opt=False, mlp_shard_opt=False),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=256, vocab=512, train=TrainSettings())
