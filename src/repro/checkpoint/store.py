"""Checkpoint store: sharded-on-restore, atomic, async, keep-k.

Layout: ``<dir>/step_<N>/arrays.npz + meta.msgpack`` written to a temp
dir and atomically renamed — a crashed writer never corrupts the latest
checkpoint.  Restore re-shards onto *whatever mesh is live* (elastic
scaling: a 512-chip checkpoint restores onto 256 chips and vice versa)
because arrays are stored logically-global and ``device_put`` against the
template sharding re-lays them out.

On a real multi-host cluster each host writes its addressable shards
(process-local files) — the single-process container stores full arrays;
the code path is the same (``save`` walks ``addressable_shards``).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any

import jax
import msgpack
import numpy as np

_STEP_RE = re.compile(r"step_(\d+)$")


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, state: Any, *, keep_last: int = 3):
    """Synchronous checkpoint write (atomic)."""
    leaves, treedef = _flatten(state)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    arrays = {f"a{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "shapes": [list(a.shape) for a in arrays.values()],
    }
    with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int):
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name,
                                             "meta.msgpack")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str):
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, template: Any, step: int | None = None):
    """Restore into the *template's* pytree structure and shardings.

    The template may live on a different mesh than the checkpoint was
    written from — elastic restore re-shards via device_put."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(template)
    assert len(leaves) == len(data.files), \
        f"leaf count mismatch: {len(leaves)} vs {len(data.files)}"
    new_leaves = []
    for i, tpl in enumerate(leaves):
        arr = data[f"a{i}"]
        if hasattr(tpl, "sharding") and tpl.sharding is not None \
                and not isinstance(tpl, np.ndarray):
            new_leaves.append(jax.device_put(arr, tpl.sharding))
        else:
            new_leaves.append(jax.device_put(arr))
    return step, jax.tree_util.tree_unflatten(treedef, new_leaves)


class AsyncCheckpointer:
    """Fire-and-forget checkpointing off the training thread.

    Arrays are fetched to host synchronously (cheap vs. a train step),
    serialization/IO happens on a worker thread; ``wait()`` joins."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self.last_saved: int | None = None

    def save(self, step: int, state: Any):
        host_state = jax.tree_util.tree_map(np.asarray, state)
        self.wait()

        def work():
            save(self.ckpt_dir, step, host_state,
                 keep_last=self.keep_last)
            self.last_saved = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
