"""AdamW with global-norm clipping, cosine schedule, ZeRO-1-friendly state.

No optax in this environment — implemented directly.  Moment dtype is
configurable (bf16 moments for the 398B config, DESIGN.md §6); the master
params stay fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: Any = jnp.float32


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _decay_mask(path) -> bool:
    """No weight decay on norms/biases/1-D params."""
    names = [p.key for p in path
             if isinstance(p, jax.tree_util.DictKey)]
    if not names:
        return True
    last = names[-1]
    return last not in ("scale", "b", "conv_b", "D", "A_log")


def update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale_clip = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale_clip
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree_util.tree_map_with_path(
        upd, params, grads, state["m"], state["v"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
