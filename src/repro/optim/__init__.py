from . import adamw  # noqa: F401
from . import compression  # noqa: F401
from .adamw import AdamWConfig  # noqa: F401
