"""Horovod-style gradient compression with error feedback (paper §3.3.1).

``compressed_grad_allreduce`` runs inside shard_map over the data axes:
int8 wire format via reduce-scatter (all_to_all) + all-gather, ~4x fewer
bytes than fp32 ring allreduce.  The local quantization error is carried
in a residual pytree and re-injected next step (EF-SGD, Karimireddy et
al. 2019) so compression stays unbiased in the long run.  (The second-
stage re-quantization error after the local sum is not attributable to a
single worker and is left uncorrected — standard practice.)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

F32 = jnp.float32


def init_residuals(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, F32), params)


def _quant_chunks(parts):
    """parts (world, chunk) -> (int8, scales (world,1))."""
    scale = jnp.maximum(jnp.max(jnp.abs(parts), axis=1, keepdims=True)
                        / 127.0, 1e-30)
    q = jnp.clip(jnp.round(parts / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _compressed_mean_leaf(g, e, axes, world: int):
    """Returns (mean-of-gradients approx, new residual)."""
    shape = g.shape
    h = g.astype(F32) + e
    flat = h.reshape(-1)
    n = flat.shape[0]
    chunk = -(-n // world)
    padded = jnp.pad(flat, (0, world * chunk - n)).reshape(world, chunk)
    q, scale = _quant_chunks(padded)
    local_deq = q.astype(F32) * scale
    resid = (padded - local_deq).reshape(-1)[:n].reshape(shape)

    a2a = partial(jax.lax.all_to_all, axis_name=axes, split_axis=0,
                  concat_axis=0, tiled=True)
    mine = jnp.sum(a2a(q).astype(F32) * a2a(scale), axis=0)   # (chunk,)
    q2, s2 = _quant_chunks(mine[None])
    gq = jax.lax.all_gather(q2[0], axes, tiled=True)
    gs = jax.lax.all_gather(s2[0], axes)
    out = (gq.reshape(world, chunk).astype(F32)
           * gs.reshape(world, 1)).reshape(-1)[:n]
    return (out.reshape(shape) / world).astype(g.dtype), resid


def compressed_grad_allreduce(grads, residuals, axes, world: int):
    """Pytree version; returns (mean grads, new residuals)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(residuals)
    outs = [_compressed_mean_leaf(g, e, axes, world)
            for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return new_g, new_e
