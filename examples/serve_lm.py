"""Batched LM serving example (deliverable b, serving kind).

    PYTHONPATH=src python examples/serve_lm.py

Prefill a batch of prompts, then greedy-decode continuation tokens with
the static KV cache — the same serve_step the decode_32k / long_500k
dry-run cells lower on the 512-chip mesh.  Thin wrapper over the
production serving launcher (repro.launch.serve).
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
cmd = [sys.executable, "-m", "repro.launch.serve",
       "--arch", "lm100m", "--reduced",
       "--batch", "4", "--prompt-len", "32", "--gen", "16"]
env = dict(os.environ)
env["PYTHONPATH"] = os.path.join(REPO, "src")
print("+", " ".join(cmd))
sys.exit(subprocess.run(cmd, env=env).returncode)
