"""End-to-end LM training driver (deliverable b): ~100M-param dense LM
for a few hundred steps with checkpoint/restart.

Defaults are sized for this CPU container (reduced config, 200 steps,
a couple of minutes).  The REAL 100M run is the same command minus
``--reduced``:

    PYTHONPATH=src python examples/train_lm.py                  # CPU-sized
    PYTHONPATH=src python examples/train_lm.py --full --steps 300   # 124M

This is a thin wrapper over the production launcher
(repro.launch.train) so the example and the launcher cannot drift.
"""
import subprocess
import sys
import argparse
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true",
                help="full 124M-param lm100m config (slow on CPU)")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--fail-at", type=int, default=None,
                help="failure-injection drill")
args = ap.parse_args()

cmd = [sys.executable, "-m", "repro.launch.train",
       "--arch", "lm100m", "--steps", str(args.steps),
       "--batch", "8", "--seq", "256",
       "--ckpt-dir", "/tmp/train_lm_example_ckpt",
       "--log-every", "20"]
if not args.full:
    cmd.append("--reduced")
if args.fail_at is not None:
    cmd += ["--fail-at", str(args.fail_at)]

env = dict(os.environ)
env["PYTHONPATH"] = os.path.join(REPO, "src")
print("+", " ".join(cmd))
sys.exit(subprocess.run(cmd, env=env).returncode)
