"""Quickstart: HPTMT tables + operators in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds two tables, runs the paper's Table-2 operators (select, join,
groupby, sort), then crosses the table->tensor boundary (paper Listing 3)
and runs a tensor op — all inside one jitted program.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import local_ops as L
from repro.core.table import Table

# --- build tables (stage 2 of the paper's workflow) -----------------------
orders = Table.from_dict({
    "order_id": np.arange(8, dtype=np.int32),
    "customer": np.array([0, 1, 0, 2, 1, 0, 2, 2], np.int32),
    "amount": np.array([10., 20., 30., 40., 50., 60., 70., 80.],
                       np.float32),
})
customers = Table.from_dict({
    "customer": np.array([0, 1, 2], np.int32),
    "segment": np.array([7, 8, 9], np.int32),   # dictionary-encoded labels
})


@jax.jit
def pipeline(orders: Table, customers: Table):
    # Select: orders over 25
    big = L.select(orders, orders["amount"] > 25.0)
    # Join: attach customer segment
    joined = L.join(big, customers, left_on=["customer"],
                    out_capacity=big.capacity)
    # GroupBy + Aggregate: revenue per segment
    rev = L.groupby_aggregate(joined, ["segment"],
                              {"amount": ["sum", "count"]})
    # OrderBy: largest segment first
    rev = L.sort_values(rev, ["amount_sum"], ascending=False)
    # stage 3: Table -> tensor handoff; stage 4: a tensor op
    X = rev.to_tensor(["amount_sum", "amount_count"])
    total = jnp.sum(X[:, 0])
    return rev, total


rev, total = pipeline(orders, customers)
out = rev.to_numpy()
print("revenue by segment (sorted):")
for seg, s, c in zip(out["segment"], out["amount_sum"],
                     out["amount_count"]):
    print(f"  segment={seg}  sum={s:8.1f}  count={int(c)}")
print(f"total revenue over threshold: {float(total):.1f}")
assert abs(float(total) - 330.0) < 1e-3
print("quickstart OK")
