"""UNOMT end-to-end (paper §4): data engineering + deep learning in ONE
distributed program with a single runtime — the paper's headline demo.

    PYTHONPATH=src python examples/unomt_e2e.py \
        [--parallelism 4] [--rows 20000] [--steps 200] [--compress]
        [--fail-at 120]   # inject a failure; training restarts from ckpt

Stages (paper Fig. 5):
  1. spawn workers        -> forced host devices + HptmtContext (mesh)
  2. data engineering     -> distributed join/unique/isin/scale pipeline
  3. table -> tensor      -> feature_label_arrays inside the same program
  4. data analytics       -> BSP DDP training of the drug-response net
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--parallelism", type=int, default=4)
    ap.add_argument("--rows", type=int, default=20_000)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient allreduce")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (restart drill)")
    ap.add_argument("--ckpt-dir", default="/tmp/unomt_ckpt")
    args = ap.parse_args()

    if args.parallelism > 1 and "XLA_FLAGS" not in os.environ:
        # stage 1: single-command spawn (the paper's mpirun equivalent)
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.parallelism}")
        os.execv(sys.executable, [sys.executable] + sys.argv)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import dist_ops as D
    from repro.core.context import make_context
    from repro.data.unomt import (feature_label_arrays, gen_unomt_tables,
                                  unomt_dist_pipeline)
    from repro.models import unomt_net
    from repro.optim import adamw, compression
    from repro.runtime.ddp import make_ddp_train_step
    from repro.runtime.trainer import (FailureInjector, Trainer,
                                       run_with_restarts)

    world = min(args.parallelism, len(jax.devices()))
    ctx = make_context(Mesh(np.array(jax.devices()[:world]), ("data",)))
    print(f"[stage 1] {world} workers, mesh axes {ctx.mesh.axis_names}")

    # ---- stage 2: distributed data engineering --------------------------
    raw = gen_unomt_tables(n_response=args.rows, n_drugs=512, n_cells=256,
                           seed=0)
    caps = {k: max((len(next(iter(v.values()))) // world) * 2, 8)
            for k, v in raw.items()}
    gt = {k: D.distribute_table(ctx, v, capacity_per_shard=caps[k])
          for k, v in raw.items()}
    pipe = D.DistributedPipeline(
        ctx, lambda c, r, de, fp, rn: unomt_dist_pipeline(
            c, r, de, fp, rn, overcommit=3.0))
    feat, dropped = pipe(gt["response"], gt["descriptors"],
                         gt["fingerprints"], gt["rna"])
    n_rows = int(np.sum(np.asarray(feat.nvalid)))
    print(f"[stage 2] features: {n_rows} rows "
          f"(dropped={int(np.max(np.asarray(dropped)))})")

    # ---- stage 3: table -> tensors (still on the mesh) -------------------
    X, y, mask = D.DistributedPipeline(
        ctx, lambda c, t: feature_label_arrays(t))(feat)
    X = X.reshape(-1, X.shape[-1])
    y, mask = y.reshape(-1), mask.reshape(-1)
    print(f"[stage 3] X {X.shape} sharded {X.sharding.spec}")

    # ---- stage 4: BSP DDP training ---------------------------------------
    net_cfg = unomt_net.UnomtNetConfig(n_features=X.shape[1],
                                       d_hidden=512, n_res_blocks=3,
                                       n_dense_tail=2, dropout=0.0)
    params = unomt_net.init(jax.random.PRNGKey(0), net_cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=20,
                                total_steps=args.steps)
    ddp_step = make_ddp_train_step(
        lambda p, b: unomt_net.mse_loss(p, net_cfg, b), opt_cfg, ctx,
        compress=args.compress)

    def step_fn(state, batch):
        params, opt, res = state
        params, opt, res, metrics = ddp_step(params, opt, res, batch)
        return (params, opt, res), metrics

    def batches(start_step):
        while True:
            yield {"x": X, "y": y, "mask": mask}

    # replicate state on the mesh explicitly so checkpoint restore puts
    # arrays back mesh-wide (not committed to device 0)
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(ctx.mesh, P())
    put = lambda tree: jax.tree_util.tree_map(
        lambda x: jax.device_put(x, rep), tree)
    state0 = (put(params), put(adamw.init(params, opt_cfg)),
              put(compression.init_residuals(params)))
    trainer = Trainer(step_fn=step_fn, ckpt_dir=args.ckpt_dir,
                      ckpt_every=50,
                      failure=FailureInjector(args.fail_at))
    state, history = run_with_restarts(batches, trainer, state0,
                                       n_steps=args.steps)
    print(f"[stage 4] loss {history[0]['loss']:.4f} -> "
          f"{history[-1]['loss']:.4f} over {len(history)} steps "
          f"({'compressed' if args.compress else 'exact'} allreduce)")
    stragglers = [h for h in history if h.get("straggler")]
    if stragglers:
        print(f"[monitor] {len(stragglers)} straggler steps flagged")
    assert history[-1]["loss"] < history[0]["loss"]
    print("unomt_e2e OK")


if __name__ == "__main__":
    main()
