"""Distributed join (paper Fig. 4's operator) in isolation.

    PYTHONPATH=src python examples/distributed_join.py [--parallelism 4]
        [--local-impl sortmerge|hash]

Shows the HPTMT recipe explicitly: hash-partition -> all_to_all shuffle ->
local join (sort-merge by default; ``--local-impl hash`` runs the bucketed
Pallas hash-join backend instead), and verifies the result against a
single-partition oracle.
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--parallelism", type=int, default=4)
    ap.add_argument("--rows", type=int, default=50_000)
    ap.add_argument("--local-impl", default="sortmerge",
                    choices=["sortmerge", "hash"])
    args = ap.parse_args()

    if args.parallelism > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.parallelism}")
        os.execv(sys.executable, [sys.executable] + sys.argv)

    import jax
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import dist_ops as D, local_ops as L
    from repro.core.context import make_context
    from repro.core.table import Table

    world = min(args.parallelism, len(jax.devices()))
    ctx = make_context(Mesh(np.array(jax.devices()[:world]), ("data",)))
    rng = np.random.default_rng(0)
    n = args.rows
    left = {"k": rng.integers(0, n // 10, n).astype(np.int32),
            "lv": rng.normal(size=n).astype(np.float32)}
    right = {"k": rng.integers(0, n // 10, n).astype(np.int32),
             "rv": rng.normal(size=n).astype(np.float32)}

    cap = (n // world) * 2
    gl = D.distribute_table(ctx, left, capacity_per_shard=cap)
    gr = D.distribute_table(ctx, right, capacity_per_shard=cap)
    sizes = None
    if args.local_impl == "hash":
        from repro.kernels.hash_join import workload_hash_join_sizes
        sizes = workload_hash_join_sizes(max(n // 10 // world, 1))
    pipe = D.DistributedPipeline(
        ctx, lambda c, a, b: D.dist_join(c, a, b, left_on=["k"],
                                         out_capacity=cap * 8,
                                         overcommit=3.0,
                                         local_impl=args.local_impl,
                                         local_join_sizes=sizes))
    out, dropped = pipe(gl, gr)
    got = D.collect_table(ctx, out)
    print(f"parallelism={world}: joined {len(got['k'])} rows "
          f"(dropped={int(np.max(np.asarray(dropped)))})")

    # single-partition oracle on a sample
    lt, rt = Table.from_dict(left), Table.from_dict(right)
    want = L.join(lt, rt, left_on=["k"], out_capacity=cap * 8 * world)
    assert len(got["k"]) == int(want.nvalid), \
        (len(got["k"]), int(want.nvalid))
    print("distributed join == local oracle row count: OK")


if __name__ == "__main__":
    main()
